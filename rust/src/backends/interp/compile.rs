//! Lowering pass: typed AST → slot-resolved executable form.
//!
//! The paper's thesis is that the *compiler* owns the parallel hot path; for
//! the CPU backend that means name resolution happens here, once, and never
//! inside the per-vertex / per-edge loop. This pass walks the typed AST a
//! single time and produces a compact op tree whose operands are dense
//! indices:
//!
//! - **properties** → `u32` slots into `Env`'s `Vec<PropData>`;
//! - **shared scalars** (params, host locals, reduction cells) → `u32` slots
//!   into `Vec<ScalarCell>`;
//! - **kernel locals and loop elements** → register numbers into a small
//!   per-worker frame (`[Val]`), sized at compile time;
//! - **node sets** → slots into `Vec<Vec<Node>>`.
//!
//! No `String` survives into execution ([`super::eval`] and the drivers in
//! [`super`] consume only this form); names are kept solely in the
//! [`Program`] tables so results can be handed back by name at the API
//! boundary.
//!
//! The pass also recognizes the frontier-eligible `fixedPoint` shape (kernel
//! filtered on a bool flag + flag ping-pong) so the executor can run a
//! sparse worklist instead of dense sweeps — see [`FrontierInfo`].

use crate::dsl::ast::*;
use crate::ir::slots::Interner;
use crate::ir::ScalarTy;
use crate::sema::TypedFunction;
use anyhow::{anyhow, bail, Result};

// ---------------------------------------------------------------------------
// Slot-resolved form
// ---------------------------------------------------------------------------

/// Where a node/edge id comes from when indexing a property.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Idx {
    /// a register of the current kernel frame (loop elements, locals)
    Reg(u32),
    /// a shared scalar cell (host-side element references like `src`)
    Scalar(u32),
}

/// Slot-resolved expression. Every operand is a dense index; evaluation
/// performs no name lookup of any kind.
#[derive(Clone, Debug)]
pub enum CExpr {
    ConstI(i64),
    ConstF(f64),
    ConstB(bool),
    LoadReg(u32),
    LoadScalar(u32),
    LoadProp { prop: u32, idx: Idx },
    Unary { op: UnOp, expr: Box<CExpr> },
    Binary { op: BinOp, lhs: Box<CExpr>, rhs: Box<CExpr> },
    Abs(Box<CExpr>),
    NumNodes,
    NumEdges,
    MinWt,
    MaxWt,
    OutDegree(Idx),
    InDegree(Idx),
    IsAnEdge(Box<CExpr>, Box<CExpr>),
    /// `g.get_edge(v, nbr)` where `nbr` is the innermost tracked neighbor
    /// loop element: the edge id the loop is currently standing on.
    CurrentEdge,
    /// general `g.get_edge(u, w)`: binary search over sorted adjacency
    /// (still tries the tracked edge first at run time).
    EdgeLookup { u: Box<CExpr>, w: Box<CExpr> },
}

/// An extra update performed when a Min/Max construct wins.
#[derive(Clone, Debug)]
pub enum CUpdate {
    Prop { prop: u32, idx: Idx, value: CExpr },
    Scalar { slot: u32, value: CExpr },
}

/// Domain of a device-side loop.
#[derive(Clone, Debug)]
pub enum DevIter {
    /// out-neighbors; `dag` = restrict to BFS-DAG children (inside
    /// iterateInBFS/iterateInReverse). Non-DAG neighbor loops track the
    /// current edge id for `get_edge`.
    Neighbors { of: Idx, dag: bool },
    InNeighbors { of: Idx },
    AllNodes,
    Set(u32),
}

/// Statement inside a parallel region — executed per element by worker
/// threads; all shared mutation is atomic.
#[derive(Clone, Debug)]
pub enum DevStmt {
    /// local declaration / assignment; `coerce` is the declared type for
    /// C-style initialization narrowing
    SetReg { reg: u32, coerce: Option<ScalarTy>, value: CExpr },
    RegReduce { reg: u32, op: ReduceOp, value: CExpr },
    ScalarStore { slot: u32, value: CExpr },
    ScalarReduce { slot: u32, op: ReduceOp, value: CExpr },
    PropStore { prop: u32, idx: Idx, value: CExpr },
    PropReduce { prop: u32, idx: Idx, op: ReduceOp, value: CExpr },
    MinMax { kind: MinMax, prop: u32, idx: Idx, compare: CExpr, extra: Vec<CUpdate> },
    For { reg: u32, source: DevIter, filter: Option<CExpr>, body: Vec<DevStmt> },
    If { cond: CExpr, then: Vec<DevStmt>, els: Vec<DevStmt> },
}

/// A vertex-parallel kernel (top-level `forall` or attach body).
#[derive(Clone, Debug)]
pub struct CKernel {
    /// register holding the loop element
    pub reg: u32,
    pub source: DevIter,
    pub filter: Option<CExpr>,
    /// `filter` is exactly "bool node property `slot` is set at the loop
    /// element" — the frontier-eligibility marker
    pub filter_flag: Option<u32>,
    pub body: Vec<DevStmt>,
    /// registers needed per worker frame
    pub frame_size: usize,
}

/// Host-side iteration domain for sequential `for` loops.
#[derive(Clone, Debug)]
pub enum HostIter {
    AllNodes,
    Set(u32),
    Neighbors { of: u32 },
    InNeighbors { of: u32 },
}

/// Frontier fast path for a `fixedPoint` whose body is
/// `forall(filter(flag)) { ... }; flag = nxt; attach(nxt = False);`
/// and whose writes to `nxt` only touch the loop element, its out-neighbors
/// (push kernels, walking `offsets/adj`), or its in-neighbors (pull kernels,
/// walking `rev_offsets/srcList`). The executor then processes only flagged
/// vertices and gathers the next worklist from exactly the neighborhoods the
/// kernel can have written — `gather_out` / `gather_in` record which
/// directions the sparse gather must scan.
#[derive(Clone, Copy, Debug)]
pub struct FrontierInfo {
    /// the filter flag property (`modified`)
    pub flag: u32,
    /// the ping-pong buffer written by the kernel (`modified_nxt`)
    pub nxt: u32,
    /// some `nxt` write lands on an out-neighbor of the loop element: the
    /// gather scans the forward CSR
    pub gather_out: bool,
    /// some `nxt` write lands on an in-neighbor (reverse-CSR pull): the
    /// gather scans `rev_offsets/srcList`
    pub gather_in: bool,
    /// the kernel body is *exactly* the canonical edge relaxation — the
    /// stronger shape that admits pull rounds and delta-stepping (the
    /// executor runs the relaxation natively instead of the compiled body)
    pub relax: Option<RelaxInfo>,
}

/// The canonical relaxation shape: for every frontier vertex `v` and each
/// out-neighbor `w`, `dist[w] = Min(dist[w], dist[v] (+ weight[e]))`, with
/// the ping-pong mark as the only side effect. SSSP and min-label CC both
/// compile to it. Because the whole per-edge effect is this one idempotent
/// Min, the executor may legally re-order, re-direct (pull over
/// `rev_offsets/srcList`), or re-bucket (delta-stepping) the edge visits.
#[derive(Clone, Copy, Debug)]
pub struct RelaxInfo {
    /// the integer distance/label property being minimized
    pub dist: u32,
    /// edge-weight property added to `dist[v]` (`None` = weight-free, e.g.
    /// min-label CC; delta-stepping requires `Some`)
    pub weight: Option<u32>,
}

/// Host-level statement.
#[derive(Clone, Debug)]
pub enum HostStmt {
    /// (re-)materialize a declared property array
    AllocProp { prop: u32, ty: ScalarTy, edge: bool },
    DeclScalar { slot: u32, ty: ScalarTy, init: Option<CExpr> },
    SetScalar { slot: u32, value: CExpr },
    ScalarReduce { slot: u32, op: ReduceOp, value: CExpr },
    /// `src.dist = 0;` — single-element store through a host scalar
    PropElemStore { prop: u32, obj: u32, value: CExpr },
    /// whole-property copy `modified = modified_nxt;`
    PropCopy { dst: u32, src: u32 },
    /// `g.attachNodeProperty(p = e, ...)` — N-wide parallel fill
    Attach { inits: Vec<(u32, CExpr)> },
    Kernel(CKernel),
    SeqFor { var: u32, source: HostIter, filter: Option<CExpr>, body: Vec<HostStmt> },
    IterateBFS {
        reg: u32,
        from: u32,
        body: Vec<DevStmt>,
        reverse: Option<(CExpr, Vec<DevStmt>)>,
        frame_size: usize,
    },
    FixedPoint { var: u32, flag: u32, body: Vec<HostStmt>, frontier: Option<FrontierInfo> },
    DoWhile { body: Vec<HostStmt>, cond: CExpr },
    While { cond: CExpr, body: Vec<HostStmt> },
    If { cond: CExpr, then: Vec<HostStmt>, els: Vec<HostStmt> },
    Return { value: CExpr },
}

/// Property slot metadata (drives `Env` allocation) — the shared lowering's
/// table entry, re-exported so interpreter and codegen numbering agree by
/// construction (see [`crate::ir::plan::PropTable`]).
pub use crate::ir::plan::PropMeta;

/// Shared scalar slot metadata.
#[derive(Clone, Debug)]
pub struct ScalarMeta {
    pub name: String,
    pub ty: ScalarTy,
}

/// Function parameters that must be bound from [`super::Args`].
#[derive(Clone, Debug)]
pub enum ParamBind {
    Scalar { name: String, slot: u32, ty: ScalarTy },
    Set { name: String, slot: u32 },
}

/// A compiled, slot-resolved DSL function.
#[derive(Clone, Debug)]
pub struct Program {
    pub props: Vec<PropMeta>,
    pub scalars: Vec<ScalarMeta>,
    pub sets: Vec<String>,
    pub params: Vec<ParamBind>,
    pub body: Vec<HostStmt>,
}

// ---------------------------------------------------------------------------
// Compiler
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Binding {
    Prop(u32),
    Scalar(u32),
    Reg(u32),
    Set(u32),
    Graph,
}

/// Register allocator for one kernel's frame.
#[derive(Default)]
struct Frame {
    next: u32,
    max: u32,
}

impl Frame {
    fn alloc(&mut self) -> u32 {
        let r = self.next;
        self.next += 1;
        self.max = self.max.max(self.next);
        r
    }
}

struct Compiler {
    props: crate::ir::plan::PropTable,
    scalars: Interner,
    scalar_metas: Vec<ScalarMeta>,
    sets: Interner,
    scopes: Vec<std::collections::HashMap<String, Binding>>,
    /// register allocator while compiling a parallel region
    frame: Option<Frame>,
    /// innermost loop element, for bare-property reads in filters
    primary: Option<Idx>,
    /// innermost edge-tracked neighbor loop: (loop var, iteration source)
    edge_loop: Option<(String, String)>,
    /// inside iterateInBFS / iterateInReverse
    in_bfs: bool,
}

/// Compile a type-checked function to its slot-resolved form.
pub fn compile(tf: &TypedFunction) -> Result<Program> {
    let mut cc = Compiler {
        // Property slots come from the shared lowering table (declaration
        // order: params first) — the same table `DevicePlan::build` uses, so
        // interpreter and codegen numbering cannot drift.
        props: crate::ir::plan::PropTable::build(tf),
        scalars: Interner::new(),
        scalar_metas: Vec::new(),
        sets: Interner::new(),
        scopes: vec![Default::default()],
        frame: None,
        primary: None,
        edge_loop: None,
        in_bfs: false,
    };

    // Parameter bindings.
    let mut params = Vec::new();
    for p in &tf.func.params {
        match &p.ty {
            Type::Graph => {
                cc.bind(&p.name, Binding::Graph);
            }
            Type::PropNode(_) | Type::PropEdge(_) => {
                let slot = cc
                    .props
                    .slot(&p.name)
                    .ok_or_else(|| anyhow!("property parameter `{}` not registered", p.name))?;
                cc.bind(&p.name, Binding::Prop(slot));
            }
            Type::SetN(_) => {
                let slot = cc.sets.intern(&p.name);
                cc.bind(&p.name, Binding::Set(slot));
                params.push(ParamBind::Set { name: p.name.clone(), slot });
            }
            other => {
                let ty = ScalarTy::of(other);
                let slot = cc.alloc_scalar(&p.name, ty);
                cc.bind(&p.name, Binding::Scalar(slot));
                params.push(ParamBind::Scalar { name: p.name.clone(), slot, ty });
            }
        }
    }

    let body = cc.host_block(&tf.func.body)?;
    Ok(Program {
        props: cc.props.into_metas(),
        scalars: cc.scalar_metas,
        sets: cc.sets.names().to_vec(),
        params,
        body,
    })
}

impl Compiler {
    fn bind(&mut self, name: &str, b: Binding) {
        self.scopes.last_mut().unwrap().insert(name.to_string(), b);
    }

    fn lookup(&self, name: &str) -> Option<Binding> {
        self.scopes.iter().rev().find_map(|s| s.get(name)).copied()
    }

    fn alloc_scalar(&mut self, name: &str, ty: ScalarTy) -> u32 {
        let slot = self.scalars.intern(name);
        if slot as usize == self.scalar_metas.len() {
            self.scalar_metas.push(ScalarMeta { name: name.to_string(), ty });
        }
        slot
    }

    fn alloc_reg(&mut self) -> Result<u32> {
        self.frame
            .as_mut()
            .map(|f| f.alloc())
            .ok_or_else(|| anyhow!("internal: register allocation outside a parallel region"))
    }

    fn prop_slot(&self, name: &str) -> Result<u32> {
        self.props.slot(name).ok_or_else(|| anyhow!("unknown property `{name}`"))
    }

    /// Node/edge id source for `obj` in `obj.prop`.
    fn idx_of(&self, obj: &str) -> Result<Idx> {
        match self.lookup(obj) {
            Some(Binding::Reg(r)) => Ok(Idx::Reg(r)),
            Some(Binding::Scalar(s)) => Ok(Idx::Scalar(s)),
            _ => bail!("`{obj}` is not an element-valued variable"),
        }
    }

    // ---- expressions ---------------------------------------------------

    fn expr(&mut self, e: &Expr) -> Result<CExpr> {
        Ok(match e {
            Expr::IntLit(n) => CExpr::ConstI(*n),
            Expr::FloatLit(x) => CExpr::ConstF(*x),
            Expr::BoolLit(b) => CExpr::ConstB(*b),
            Expr::Inf => CExpr::ConstI(super::env::INF_I),
            Expr::Var(name) => match self.lookup(name) {
                Some(Binding::Reg(r)) => CExpr::LoadReg(r),
                Some(Binding::Scalar(s)) => CExpr::LoadScalar(s),
                Some(Binding::Prop(p)) => {
                    // bare property name: the current element's value
                    let idx = self.primary.ok_or_else(|| {
                        anyhow!("property `{name}` used without a loop element")
                    })?;
                    CExpr::LoadProp { prop: p, idx }
                }
                Some(Binding::Set(_)) | Some(Binding::Graph) => {
                    bail!("`{name}` cannot appear in an expression")
                }
                None => bail!("unknown variable `{name}`"),
            },
            Expr::Prop { obj, prop } => {
                CExpr::LoadProp { prop: self.prop_slot(prop)?, idx: self.idx_of(obj)? }
            }
            Expr::Call { recv, name, args } => return self.call(recv.as_deref(), name, args),
            Expr::Unary { op, expr } => {
                CExpr::Unary { op: *op, expr: Box::new(self.expr(expr)?) }
            }
            Expr::Binary { op, lhs, rhs } => CExpr::Binary {
                op: *op,
                lhs: Box::new(self.expr(lhs)?),
                rhs: Box::new(self.expr(rhs)?),
            },
        })
    }

    fn call(&mut self, recv: Option<&str>, name: &str, args: &[Expr]) -> Result<CExpr> {
        Ok(match (recv, name, args.len()) {
            (None, "abs", 1) => CExpr::Abs(Box::new(self.expr(&args[0])?)),
            (Some(_), "num_nodes", 0) => CExpr::NumNodes,
            (Some(_), "num_edges", 0) => CExpr::NumEdges,
            (Some(_), "minWt", 0) => CExpr::MinWt,
            (Some(_), "maxWt", 0) => CExpr::MaxWt,
            (Some(_), "is_an_edge", 2) => CExpr::IsAnEdge(
                Box::new(self.expr(&args[0])?),
                Box::new(self.expr(&args[1])?),
            ),
            (Some(_), "get_edge", 2) => {
                // `g.get_edge(v, nbr)` inside `forall (nbr in g.neighbors(v))`
                // is the edge the loop currently stands on: resolve at
                // compile time, no search at run time.
                if let (Some((var, of)), Expr::Var(u), Expr::Var(w)) =
                    (self.edge_loop.as_ref(), &args[0], &args[1])
                {
                    if w == var && u == of {
                        return Ok(CExpr::CurrentEdge);
                    }
                }
                CExpr::EdgeLookup {
                    u: Box::new(self.expr(&args[0])?),
                    w: Box::new(self.expr(&args[1])?),
                }
            }
            (Some(r), "outDegree", 0) => CExpr::OutDegree(self.idx_of(r)?),
            (Some(r), "inDegree", 0) => CExpr::InDegree(self.idx_of(r)?),
            _ => bail!(
                "unknown builtin `{}{name}/{}`",
                recv.map(|r| format!("{r}.")).unwrap_or_default(),
                args.len()
            ),
        })
    }

    // ---- host statements ----------------------------------------------

    fn host_block(&mut self, b: &[Stmt]) -> Result<Vec<HostStmt>> {
        self.scopes.push(Default::default());
        let out = self.host_block_flat(b);
        self.scopes.pop();
        out
    }

    fn host_block_flat(&mut self, b: &[Stmt]) -> Result<Vec<HostStmt>> {
        let mut out = Vec::with_capacity(b.len());
        for s in b {
            out.push(self.host_stmt(s)?);
        }
        Ok(out)
    }

    fn host_stmt(&mut self, s: &Stmt) -> Result<HostStmt> {
        Ok(match s {
            Stmt::Decl { ty, name, init, .. } => {
                if ty.is_prop() {
                    let prop = self.prop_slot(name)?;
                    self.bind(name, Binding::Prop(prop));
                    let m = self.props.meta(prop);
                    HostStmt::AllocProp { prop, ty: m.ty, edge: m.edge }
                } else {
                    let st = ScalarTy::of(ty);
                    let init = match init {
                        Some(e) => Some(self.expr(e)?),
                        None => None,
                    };
                    let slot = self.alloc_scalar(name, st);
                    self.bind(name, Binding::Scalar(slot));
                    HostStmt::DeclScalar { slot, ty: st, init }
                }
            }
            Stmt::Assign { target, value, .. } => match target {
                LValue::Var(v) if matches!(self.lookup(v), Some(Binding::Prop(_))) => {
                    let Some(Binding::Prop(dst)) = self.lookup(v) else { unreachable!() };
                    let Expr::Var(srcname) = value else {
                        bail!("property copy needs a property on the right-hand side")
                    };
                    let Some(Binding::Prop(src)) = self.lookup(srcname) else {
                        bail!("property copy needs a property on the right-hand side")
                    };
                    HostStmt::PropCopy { dst, src }
                }
                LValue::Var(v) => {
                    let Some(Binding::Scalar(slot)) = self.lookup(v) else {
                        bail!("unknown scalar `{v}`")
                    };
                    HostStmt::SetScalar { slot, value: self.expr(value)? }
                }
                LValue::Prop { obj, prop } => {
                    let Some(Binding::Scalar(objslot)) = self.lookup(obj) else {
                        bail!("`{obj}` is not a host element variable")
                    };
                    HostStmt::PropElemStore {
                        prop: self.prop_slot(prop)?,
                        obj: objslot,
                        value: self.expr(value)?,
                    }
                }
            },
            Stmt::Reduce { target, op, value, .. } => {
                let LValue::Var(v) = target else { bail!("host reduction target must be scalar") };
                let Some(Binding::Scalar(slot)) = self.lookup(v) else {
                    bail!("unknown scalar `{v}`")
                };
                HostStmt::ScalarReduce { slot, op: *op, value: self.expr(value)? }
            }
            Stmt::AttachNodeProperty { inits, .. } => {
                let mut cinits = Vec::with_capacity(inits.len());
                for (p, e) in inits {
                    cinits.push((self.prop_slot(p)?, self.expr(e)?));
                }
                HostStmt::Attach { inits: cinits }
            }
            Stmt::For { iter, body, parallel: true, .. } => {
                HostStmt::Kernel(self.kernel(iter, body)?)
            }
            Stmt::For { iter, body, parallel: false, .. } => {
                let source = match &iter.source {
                    IterSource::Nodes { .. } => HostIter::AllNodes,
                    IterSource::Set { set } => match self.lookup(set) {
                        Some(Binding::Set(s)) => HostIter::Set(s),
                        _ => bail!("`{set}` is not a SetN parameter"),
                    },
                    IterSource::Neighbors { of, .. } => match self.lookup(of) {
                        Some(Binding::Scalar(s)) => HostIter::Neighbors { of: s },
                        _ => bail!("`{of}` is not a host node variable"),
                    },
                    IterSource::NodesTo { of, .. } => match self.lookup(of) {
                        Some(Binding::Scalar(s)) => HostIter::InNeighbors { of: s },
                        _ => bail!("`{of}` is not a host node variable"),
                    },
                };
                self.scopes.push(Default::default());
                let var = self.alloc_scalar(&iter.var, ScalarTy::I32);
                self.bind(&iter.var, Binding::Scalar(var));
                let saved_primary = self.primary;
                self.primary = Some(Idx::Scalar(var));
                let filter = match &iter.filter {
                    Some(f) => Some(self.expr(f)?),
                    None => None,
                };
                self.primary = saved_primary;
                let body = self.host_block_flat(body);
                self.scopes.pop();
                HostStmt::SeqFor { var, source, filter, body: body? }
            }
            Stmt::IterateBFS { var, from, body, reverse, .. } => {
                let Some(Binding::Scalar(from_slot)) = self.lookup(from) else {
                    bail!("BFS source `{from}` is not a host node variable")
                };
                let saved_frame = self.frame.replace(Frame::default());
                let saved_primary = self.primary;
                let saved_bfs = self.in_bfs;
                self.scopes.push(Default::default());
                self.in_bfs = true;
                let result = (|| {
                    let reg = self.alloc_reg()?;
                    self.bind(var, Binding::Reg(reg));
                    self.primary = Some(Idx::Reg(reg));
                    let cbody = self.dev_block(body)?;
                    let crev = match reverse {
                        Some((cond, rbody)) => Some((self.expr(cond)?, self.dev_block(rbody)?)),
                        None => None,
                    };
                    Ok::<_, anyhow::Error>((reg, cbody, crev))
                })();
                self.scopes.pop();
                self.in_bfs = saved_bfs;
                self.primary = saved_primary;
                let frame = std::mem::replace(&mut self.frame, saved_frame).unwrap();
                let (reg, body, reverse) = result?;
                HostStmt::IterateBFS {
                    reg,
                    from: from_slot,
                    body,
                    reverse,
                    frame_size: frame.max as usize,
                }
            }
            Stmt::FixedPoint { var, cond, body, .. } => {
                let Some(Binding::Scalar(var_slot)) = self.lookup(var) else {
                    bail!("fixedPoint variable `{var}` is not a declared scalar")
                };
                let flag_name = crate::ir::or_flag_prop(cond)
                    .ok_or_else(|| anyhow!("unsupported fixedPoint condition form"))?;
                let flag = self.prop_slot(&flag_name)?;
                let cbody = self.host_block(body)?;
                let frontier = self.detect_frontier(&cbody, flag);
                HostStmt::FixedPoint { var: var_slot, flag, body: cbody, frontier }
            }
            Stmt::DoWhile { body, cond, .. } => {
                let body = self.host_block(body)?;
                HostStmt::DoWhile { body, cond: self.expr(cond)? }
            }
            Stmt::While { cond, body, .. } => {
                let cond = self.expr(cond)?;
                HostStmt::While { cond, body: self.host_block(body)? }
            }
            Stmt::If { cond, then, els, .. } => {
                let cond = self.expr(cond)?;
                let then = self.host_block(then)?;
                let els = match els {
                    Some(e) => self.host_block(e)?,
                    None => Vec::new(),
                };
                HostStmt::If { cond, then, els }
            }
            Stmt::Return { value, .. } => HostStmt::Return { value: self.expr(value)? },
            Stmt::MinMaxAssign { .. } => bail!("Min/Max construct outside a parallel loop"),
        })
    }

    // ---- device statements ---------------------------------------------

    fn kernel(&mut self, iter: &Iterator_, body: &[Stmt]) -> Result<CKernel> {
        let source = match &iter.source {
            IterSource::Nodes { .. } => DevIter::AllNodes,
            IterSource::Set { set } => match self.lookup(set) {
                Some(Binding::Set(s)) => DevIter::Set(s),
                _ => bail!("`{set}` is not a SetN parameter"),
            },
            IterSource::Neighbors { of, .. } => {
                DevIter::Neighbors { of: self.idx_of(of)?, dag: false }
            }
            IterSource::NodesTo { of, .. } => DevIter::InNeighbors { of: self.idx_of(of)? },
        };
        let saved_frame = self.frame.replace(Frame::default());
        let saved_primary = self.primary;
        self.scopes.push(Default::default());
        let result = (|| {
            let reg = self.alloc_reg()?;
            self.bind(&iter.var, Binding::Reg(reg));
            self.primary = Some(Idx::Reg(reg));
            let filter = match &iter.filter {
                Some(f) => Some(self.expr(f)?),
                None => None,
            };
            let cbody = self.dev_block(body)?;
            Ok::<_, anyhow::Error>((reg, filter, cbody))
        })();
        self.scopes.pop();
        self.primary = saved_primary;
        let frame = std::mem::replace(&mut self.frame, saved_frame).unwrap();
        let (reg, filter, body) = result?;
        let filter_flag = self.filter_flag_of(&filter, reg);
        Ok(CKernel { reg, source, filter, filter_flag, body, frame_size: frame.max as usize })
    }

    fn dev_block(&mut self, b: &[Stmt]) -> Result<Vec<DevStmt>> {
        self.scopes.push(Default::default());
        let out = (|| {
            let mut out = Vec::with_capacity(b.len());
            for s in b {
                out.push(self.dev_stmt(s)?);
            }
            Ok(out)
        })();
        self.scopes.pop();
        out
    }

    fn dev_stmt(&mut self, s: &Stmt) -> Result<DevStmt> {
        Ok(match s {
            Stmt::Decl { ty, name, init, .. } => {
                if ty.is_prop() {
                    bail!("property declaration inside a parallel region");
                }
                let st = ScalarTy::of(ty);
                let value = match init {
                    Some(e) => self.expr(e)?,
                    None => zero_expr(st),
                };
                let reg = self.alloc_reg()?;
                self.bind(name, Binding::Reg(reg));
                DevStmt::SetReg { reg, coerce: Some(st), value }
            }
            Stmt::Assign { target, value, .. } => {
                // read-modify-write on shared properties becomes an atomic
                // reduction, as in the generated GPU code
                if let Some((t, op, rhs)) = crate::ir::analyze::as_reduction(target, value) {
                    if let LValue::Prop { obj, prop } = &t {
                        return Ok(DevStmt::PropReduce {
                            prop: self.prop_slot(prop)?,
                            idx: self.idx_of(obj)?,
                            op,
                            value: self.expr(&rhs)?,
                        });
                    }
                }
                match target {
                    LValue::Var(v) => match self.lookup(v) {
                        Some(Binding::Reg(r)) => {
                            DevStmt::SetReg { reg: r, coerce: None, value: self.expr(value)? }
                        }
                        Some(Binding::Scalar(slot)) => {
                            // shared scalar write (rare; e.g. flags) — atomic
                            DevStmt::ScalarStore { slot, value: self.expr(value)? }
                        }
                        _ => bail!("cannot assign to `{v}` inside a parallel region"),
                    },
                    LValue::Prop { obj, prop } => DevStmt::PropStore {
                        prop: self.prop_slot(prop)?,
                        idx: self.idx_of(obj)?,
                        value: self.expr(value)?,
                    },
                }
            }
            Stmt::Reduce { target, op, value, .. } => match target {
                LValue::Var(v) => match self.lookup(v) {
                    Some(Binding::Reg(r)) => {
                        DevStmt::RegReduce { reg: r, op: *op, value: self.expr(value)? }
                    }
                    Some(Binding::Scalar(slot)) => {
                        DevStmt::ScalarReduce { slot, op: *op, value: self.expr(value)? }
                    }
                    _ => bail!("cannot reduce into `{v}` inside a parallel region"),
                },
                LValue::Prop { obj, prop } => DevStmt::PropReduce {
                    prop: self.prop_slot(prop)?,
                    idx: self.idx_of(obj)?,
                    op: *op,
                    value: self.expr(value)?,
                },
            },
            Stmt::MinMaxAssign { kind, target, compare, extra, .. } => {
                let LValue::Prop { obj, prop } = target else {
                    bail!("Min/Max target must be a property")
                };
                let prop = self.prop_slot(prop)?;
                let idx = self.idx_of(obj)?;
                let compare = self.expr(compare)?;
                let mut cextra = Vec::with_capacity(extra.len());
                for (t, v) in extra {
                    let value = self.expr(v)?;
                    cextra.push(match t {
                        LValue::Prop { obj, prop } => CUpdate::Prop {
                            prop: self.prop_slot(prop)?,
                            idx: self.idx_of(obj)?,
                            value,
                        },
                        LValue::Var(name) => match self.lookup(name) {
                            Some(Binding::Scalar(slot)) => CUpdate::Scalar { slot, value },
                            _ => bail!("Min/Max extra target `{name}` must be a shared scalar"),
                        },
                    });
                }
                DevStmt::MinMax { kind: *kind, prop, idx, compare, extra: cextra }
            }
            Stmt::For { iter, body, .. } => {
                // nested loops run sequentially within the worker thread
                // (same-kernel folding, as the paper's generated code does)
                let (source, tracks_edge) = match &iter.source {
                    IterSource::Neighbors { of, .. } => {
                        let dag = self.in_bfs;
                        (DevIter::Neighbors { of: self.idx_of(of)?, dag }, !dag)
                    }
                    IterSource::NodesTo { of, .. } => {
                        (DevIter::InNeighbors { of: self.idx_of(of)? }, false)
                    }
                    IterSource::Nodes { .. } => (DevIter::AllNodes, false),
                    IterSource::Set { set } => match self.lookup(set) {
                        Some(Binding::Set(s)) => (DevIter::Set(s), false),
                        _ => bail!("`{set}` is not a SetN parameter"),
                    },
                };
                self.scopes.push(Default::default());
                let saved_primary = self.primary;
                let saved_edge_loop = self.edge_loop.clone();
                let result = (|| {
                    let reg = self.alloc_reg()?;
                    self.bind(&iter.var, Binding::Reg(reg));
                    self.primary = Some(Idx::Reg(reg));
                    if tracks_edge {
                        if let IterSource::Neighbors { of, .. } = &iter.source {
                            self.edge_loop = Some((iter.var.clone(), of.clone()));
                        }
                    } else if matches!(source, DevIter::Neighbors { dag: true, .. }) {
                        self.edge_loop = None;
                    }
                    let filter = match &iter.filter {
                        Some(f) => Some(self.expr(f)?),
                        None => None,
                    };
                    let mut cbody = Vec::with_capacity(body.len());
                    for st in body {
                        cbody.push(self.dev_stmt(st)?);
                    }
                    Ok::<_, anyhow::Error>((reg, filter, cbody))
                })();
                self.scopes.pop();
                self.primary = saved_primary;
                self.edge_loop = saved_edge_loop;
                let (reg, filter, body) = result?;
                DevStmt::For { reg, source, filter, body }
            }
            Stmt::If { cond, then, els, .. } => {
                let cond = self.expr(cond)?;
                let then = self.dev_block(then)?;
                let els = match els {
                    Some(e) => self.dev_block(e)?,
                    None => Vec::new(),
                };
                DevStmt::If { cond, then, els }
            }
            other => bail!("statement not allowed inside a parallel region: {other:?}"),
        })
    }

    // ---- frontier pattern recognition ----------------------------------

    /// Is the kernel filter exactly "bool node property at the loop element"?
    fn filter_flag_of(&self, filter: &Option<CExpr>, reg: u32) -> Option<u32> {
        let prop = match filter.as_ref()? {
            CExpr::LoadProp { prop, idx: Idx::Reg(r) } if *r == reg => *prop,
            CExpr::Binary { op: BinOp::Eq, lhs, rhs } => match (&**lhs, &**rhs) {
                (CExpr::LoadProp { prop, idx: Idx::Reg(r) }, CExpr::ConstB(true))
                    if *r == reg =>
                {
                    *prop
                }
                _ => return None,
            },
            _ => return None,
        };
        let m = self.props.meta(prop);
        (m.ty == ScalarTy::Bool && !m.edge).then_some(prop)
    }

    /// Recognize the frontier-eligible fixedPoint body shape.
    fn detect_frontier(&self, body: &[HostStmt], flag: u32) -> Option<FrontierInfo> {
        let [HostStmt::Kernel(k), HostStmt::PropCopy { dst, src }, HostStmt::Attach { inits }] =
            body
        else {
            return None;
        };
        if *dst != flag || k.filter_flag != Some(flag) {
            return None;
        }
        if !matches!(k.source, DevIter::AllNodes) {
            return None;
        }
        let nxt = *src;
        // the reset must clear exactly the ping-pong buffer
        let [(reset_prop, CExpr::ConstB(false))] = inits.as_slice() else { return None };
        if *reset_prop != nxt {
            return None;
        }
        // the kernel must not touch the flag itself, and all its writes to
        // `nxt` must target the loop element, its out-neighbors, or its
        // in-neighbors — the union of neighborhoods the sparse gather scans
        if writes_prop(&k.body, flag) {
            return None;
        }
        let mut allowed = vec![(k.reg, Near::Root)];
        let mut dirs = GatherDirs::default();
        if !writes_only_near(&k.body, nxt, k.reg, &mut allowed, &mut dirs) {
            return None;
        }
        let relax = if dirs.in_ { None } else { self.detect_relax(k, nxt) };
        Some(FrontierInfo { flag, nxt, gather_out: dirs.out, gather_in: dirs.in_, relax })
    }

    /// Recognize the canonical push-relaxation kernel body (see
    /// [`RelaxInfo`]): one out-neighbor loop whose entire effect is a single
    /// Min into an integer distance property plus the ping-pong mark.
    fn detect_relax(&self, k: &CKernel, nxt: u32) -> Option<RelaxInfo> {
        let [DevStmt::For { reg: w, source, filter: None, body: inner }] = k.body.as_slice()
        else {
            return None;
        };
        let DevIter::Neighbors { of: Idx::Reg(of), dag: false } = source else { return None };
        if *of != k.reg {
            return None;
        }
        // optional `edge e = g.get_edge(v, nbr);` binding the current edge
        let (edge_reg, relax) = match inner.as_slice() {
            [DevStmt::SetReg { reg, coerce: _, value: CExpr::CurrentEdge }, m] => (Some(*reg), m),
            [m] => (None, m),
            _ => return None,
        };
        let DevStmt::MinMax { kind: MinMax::Min, prop: dist, idx: Idx::Reg(t), compare, extra } =
            relax
        else {
            return None;
        };
        if *t != *w {
            return None;
        }
        // the only extra update is the ping-pong mark on the relaxed vertex
        let [CUpdate::Prop { prop: mark, idx: Idx::Reg(mi), value: CExpr::ConstB(true) }] =
            extra.as_slice()
        else {
            return None;
        };
        if *mark != nxt || *mi != *w {
            return None;
        }
        let dist_at_root = |e: &CExpr| {
            matches!(e, CExpr::LoadProp { prop, idx: Idx::Reg(r) } if *prop == *dist && *r == k.reg)
        };
        let weight = match compare {
            // weight-free: dist[w] = Min(dist[w], dist[v])
            e if dist_at_root(e) => None,
            // weighted: dist[w] = Min(dist[w], dist[v] + weight[e])
            CExpr::Binary { op: BinOp::Add, lhs, rhs } if dist_at_root(lhs) => match &**rhs {
                CExpr::LoadProp { prop: wp, idx: Idx::Reg(r) }
                    if Some(*r) == edge_reg && self.props.meta(*wp).edge =>
                {
                    Some(*wp)
                }
                _ => return None,
            },
            _ => return None,
        };
        // bucketing and the pull round assume integer arithmetic
        let int = |ty: ScalarTy| matches!(ty, ScalarTy::I32 | ScalarTy::I64);
        if !int(self.props.meta(*dist).ty) || self.props.meta(*dist).edge {
            return None;
        }
        if let Some(wp) = weight {
            if !int(self.props.meta(wp).ty) {
                return None;
            }
        }
        Some(RelaxInfo { dist: *dist, weight })
    }
}

fn zero_expr(st: ScalarTy) -> CExpr {
    match st {
        ScalarTy::F32 | ScalarTy::F64 => CExpr::ConstF(0.0),
        ScalarTy::Bool => CExpr::ConstB(false),
        _ => CExpr::ConstI(0),
    }
}

/// Does the block write property `prop` anywhere?
fn writes_prop(body: &[DevStmt], prop: u32) -> bool {
    body.iter().any(|s| match s {
        DevStmt::PropStore { prop: p, .. } | DevStmt::PropReduce { prop: p, .. } => *p == prop,
        DevStmt::MinMax { prop: p, extra, .. } => {
            *p == prop
                || extra.iter().any(|u| matches!(u, CUpdate::Prop { prop: q, .. } if *q == prop))
        }
        DevStmt::For { body, .. } => writes_prop(body, prop),
        DevStmt::If { then, els, .. } => writes_prop(then, prop) || writes_prop(els, prop),
        _ => false,
    })
}

/// Which 1-hop neighborhood of the root element a register ranges over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Near {
    /// the kernel's loop element itself
    Root,
    /// a loop over the root's direct out-neighbors
    Out,
    /// a loop over the root's direct in-neighbors (reverse-CSR pull)
    In,
}

/// Directions the sparse gather must scan, accumulated from the registers
/// that actually receive `nxt` writes (a pull kernel that merely *reads*
/// in-neighbors does not force an in-gather).
#[derive(Clone, Copy, Debug, Default)]
struct GatherDirs {
    out: bool,
    in_: bool,
}

/// Are all writes to `prop` indexed by the kernel element or by loop
/// variables ranging over its *direct* out- or in-neighbors? (`allowed`
/// holds the eligible registers with their neighborhood direction; neighbor
/// loops of the root element extend it for their body only. Loops over a
/// neighbor's neighbors — 2-hop writes — contribute nothing, so such kernels
/// stay on the dense schedule.) Every write that lands on an Out/In register
/// marks that direction in `dirs`.
fn writes_only_near(
    body: &[DevStmt],
    prop: u32,
    root: u32,
    allowed: &mut Vec<(u32, Near)>,
    dirs: &mut GatherDirs,
) -> bool {
    fn idx_ok(idx: &Idx, allowed: &[(u32, Near)], dirs: &mut GatherDirs) -> bool {
        let Idx::Reg(r) = idx else { return false };
        match allowed.iter().find(|(a, _)| a == r) {
            Some((_, Near::Root)) => true,
            Some((_, Near::Out)) => {
                dirs.out = true;
                true
            }
            Some((_, Near::In)) => {
                dirs.in_ = true;
                true
            }
            None => false,
        }
    }
    body.iter().all(|s| match s {
        DevStmt::PropStore { prop: p, idx, .. } | DevStmt::PropReduce { prop: p, idx, .. } => {
            *p != prop || idx_ok(idx, allowed, dirs)
        }
        DevStmt::MinMax { prop: p, idx, extra, .. } => {
            (*p != prop || idx_ok(idx, allowed, dirs))
                && extra.iter().all(|u| match u {
                    CUpdate::Prop { prop: q, idx, .. } => *q != prop || idx_ok(idx, allowed, dirs),
                    CUpdate::Scalar { .. } => true,
                })
        }
        DevStmt::For { reg, source, body, .. } => {
            let near = match source {
                DevIter::Neighbors { of: Idx::Reg(r), dag: false } if *r == root => {
                    Some(Near::Out)
                }
                DevIter::InNeighbors { of: Idx::Reg(r) } if *r == root => Some(Near::In),
                _ => None,
            };
            if let Some(n) = near {
                allowed.push((*reg, n));
            }
            let ok = writes_only_near(body, prop, root, allowed, dirs);
            if near.is_some() {
                allowed.pop();
            }
            ok
        }
        DevStmt::If { then, els, .. } => {
            writes_only_near(then, prop, root, allowed, dirs)
                && writes_only_near(els, prop, root, allowed, dirs)
        }
        _ => true,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse;
    use crate::sema::check_function;

    fn compile_src(src: &str) -> Program {
        let fns = parse(src).unwrap();
        let tf = check_function(&fns[0]).unwrap();
        compile(&tf).unwrap()
    }

    fn compile_program(p: &str) -> Program {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(p);
        let src = std::fs::read_to_string(&path).unwrap();
        compile_src(&src)
    }

    #[test]
    fn all_shipped_programs_compile() {
        for p in ["bc.sp", "pr.sp", "sssp.sp", "tc.sp", "cc.sp", "bfs.sp"] {
            let prog = compile_program(p);
            assert!(!prog.body.is_empty(), "{p}");
        }
    }

    #[test]
    fn sssp_slots_and_frontier() {
        let prog = compile_program("sssp.sp");
        // props in declaration order: params first, then body declarations
        let names: Vec<&str> = prog.props.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, vec!["dist", "weight", "modified", "modified_nxt"]);
        assert!(prog.props[1].edge && prog.props[1].param);
        assert!(!prog.props[2].param);
        // the fixedPoint is frontier-eligible: filter on `modified`,
        // ping-pong into `modified_nxt`
        let fp = prog
            .body
            .iter()
            .find_map(|s| match s {
                HostStmt::FixedPoint { frontier, .. } => Some(*frontier),
                _ => None,
            })
            .expect("sssp has a fixedPoint");
        let f = fp.expect("sssp fixedPoint is frontier-eligible");
        assert_eq!(prog.props[f.flag as usize].name, "modified");
        assert_eq!(prog.props[f.nxt as usize].name, "modified_nxt");
        // push kernel: nxt writes land on out-neighbors only
        assert!(f.gather_out && !f.gather_in);
        // ...and the body is the canonical weighted relaxation, so pull
        // rounds and delta-stepping are admissible
        let r = f.relax.expect("sssp relax shape");
        assert_eq!(prog.props[r.dist as usize].name, "dist");
        assert_eq!(prog.props[r.weight.unwrap() as usize].name, "weight");
    }

    #[test]
    fn cc_frontier_eligible() {
        let prog = compile_program("cc.sp");
        let fp = prog
            .body
            .iter()
            .find_map(|s| match s {
                HostStmt::FixedPoint { frontier, .. } => Some(*frontier),
                _ => None,
            })
            .expect("cc has a fixedPoint");
        let f = fp.expect("cc fixedPoint should be frontier-eligible");
        // weight-free relaxation: pull-eligible but not delta-eligible
        let r = f.relax.expect("cc relax shape");
        assert!(r.weight.is_none());
    }

    #[test]
    fn get_edge_resolves_to_current_edge() {
        let prog = compile_src(
            "function f(Graph g, propNode<int> dist, propEdge<int> weight) {
               forall (v in g.nodes()) {
                 forall (nbr in g.neighbors(v)) {
                   edge e = g.get_edge(v, nbr);
                   nbr.dist = e.weight;
                 }
               }
             }",
        );
        let HostStmt::Kernel(k) = &prog.body[0] else { panic!("expected kernel") };
        let DevStmt::For { body, .. } = &k.body[0] else { panic!("expected nested loop") };
        assert!(
            matches!(&body[0], DevStmt::SetReg { value: CExpr::CurrentEdge, .. }),
            "get_edge on the loop edge should compile to CurrentEdge, got {:?}",
            body[0]
        );
    }

    #[test]
    fn kernel_frames_are_small_and_sized() {
        let prog = compile_program("sssp.sp");
        let k = prog
            .body
            .iter()
            .find_map(|s| match s {
                HostStmt::FixedPoint { body, .. } => body.iter().find_map(|s| match s {
                    HostStmt::Kernel(k) => Some(k),
                    _ => None,
                }),
                _ => None,
            })
            .expect("relax kernel");
        // v, nbr, e
        assert_eq!(k.frame_size, 3);
        assert!(k.filter_flag.is_some());
    }

    #[test]
    fn non_pingpong_fixedpoint_is_not_frontier() {
        // kernel writes the filter flag itself -> no fast path
        let prog = compile_src(
            "function f(Graph g, propNode<int> dist) {
               propNode<bool> modified;
               bool fin = False;
               g.attachNodeProperty(modified = True);
               fixedPoint until (fin: !modified) {
                 forall (v in g.nodes().filter(modified == True)) {
                   v.modified = False;
                 }
               }
             }",
        );
        let fp = prog
            .body
            .iter()
            .find_map(|s| match s {
                HostStmt::FixedPoint { frontier, .. } => Some(*frontier),
                _ => None,
            })
            .unwrap();
        assert!(fp.is_none());
    }

    fn frontier_of(prog: &Program) -> Option<FrontierInfo> {
        prog.body
            .iter()
            .find_map(|s| match s {
                HostStmt::FixedPoint { frontier, .. } => Some(*frontier),
                _ => None,
            })
            .flatten()
    }

    /// A min-label propagation whose relaxation *pulls* along reverse edges:
    /// every `nxt` write lands on an in-neighbor of the loop element.
    const PULL_CC: &str = "function Compute_CC_Pull(Graph g, propNode<int> comp) {
        propNode<bool> modified;
        propNode<bool> modified_nxt;
        bool finished = False;
        forall (v in g.nodes()) {
          v.comp = v;
        }
        g.attachNodeProperty(modified = True, modified_nxt = False);
        fixedPoint until (finished: !modified) {
          forall (v in g.nodes().filter(modified == True)) {
            for (u in g.nodes_to(v)) {
              <u.comp, u.modified_nxt> = <Min(u.comp, v.comp), True>;
            }
          }
          modified = modified_nxt;
          g.attachNodeProperty(modified_nxt = False);
        }
      }";

    #[test]
    fn reverse_csr_pull_fixedpoint_is_frontier_eligible() {
        let prog = compile_src(PULL_CC);
        let f = frontier_of(&prog).expect("pull-style fixedPoint takes the sparse path");
        assert_eq!(prog.props[f.flag as usize].name, "modified");
        assert_eq!(prog.props[f.nxt as usize].name, "modified_nxt");
        // pull kernel: the gather must walk rev_offsets/srcList, not the CSR
        assert!(f.gather_in, "in-neighbor writes require the reverse-CSR gather");
        assert!(!f.gather_out, "no out-neighbor write, no forward scan");
        // direction selection only re-orients the canonical *push* shape;
        // an already-pull kernel keeps its compiled body
        assert!(f.relax.is_none(), "in-neighbor relaxations are not redirectable");
    }

    #[test]
    fn two_hop_writing_kernels_stay_dense() {
        // nxt writes land on neighbors-of-neighbors: outside the 1-hop
        // neighborhood the sparse gather scans, so no fast path
        let prog = compile_src(
            "function f(Graph g, propNode<int> dist) {
               propNode<bool> modified;
               propNode<bool> modified_nxt;
               bool fin = False;
               g.attachNodeProperty(modified = True, modified_nxt = False);
               fixedPoint until (fin: !modified) {
                 forall (v in g.nodes().filter(modified == True)) {
                   forall (nbr in g.neighbors(v)) {
                     forall (hop2 in g.neighbors(nbr)) {
                       hop2.modified_nxt = True;
                     }
                   }
                 }
                 modified = modified_nxt;
                 g.attachNodeProperty(modified_nxt = False);
               }
             }",
        );
        assert!(frontier_of(&prog).is_none(), "2-hop writes must stay on the dense schedule");
    }

    #[test]
    fn bare_scalar_names_resolve_to_slots() {
        let prog = compile_program("pr.sp");
        // every scalar has a unique slot; diff and iterCount are shared cells
        let names: Vec<&str> = prog.scalars.iter().map(|m| m.name.as_str()).collect();
        assert!(names.contains(&"diff"));
        assert!(names.contains(&"iterCount"));
        assert!(names.contains(&"beta"));
    }
}
