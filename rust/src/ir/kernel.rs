//! Backend-neutral kernel-body IR: the device-side twin of the
//! [`crate::ir::plan::HostOp`] schedule.
//!
//! Before this layer existed, every text backend re-walked the typed AST for
//! kernel *bodies*, and `codegen/body.rs` dispatched atomics, Min/Max, and
//! neighbor-loop idioms through hardcoded per-`Target` match arms. That shape
//! could only express C-family targets: a backend whose syntax is not "C with
//! different API names" (WGSL's `var<storage>` bindings, Metal's
//! `atomic_fetch_*_explicit`) had nowhere to hang its spellings.
//!
//! [`lower_kernel_body`] resolves each kernel body exactly once — in
//! [`crate::ir::plan::DevicePlan::build`], alongside the host lowering — into
//! a typed [`KernelOp`] tree:
//!
//! - property stores and atomic reductions carry their **slot** and
//!   [`ScalarTy`], so a dialect picks its atomics idiom from the type instead
//!   of re-deriving it from the AST;
//! - neighbor loops are structured CSR / reverse-CSR scans with the §3.4
//!   BFS-DAG level filter and the `.filter(...)` guard as *resolved
//!   conditions* (see [`resolve_filter`] / [`simplify_bool_cmp`]), not
//!   pre-rendered strings;
//! - the §3.5 Min/Max construct keeps its extra conditional updates and
//!   records whether a winning update must also clear the enclosing
//!   fixedPoint's OR-flag (§4.1) — context that used to be threaded through
//!   every renderer at render time.
//!
//! The tree is carried on [`crate::ir::plan::KernelPlan::body`] and rendered
//! by the one `codegen::body::render_kernel_ops` driver through a backend's
//! `KernelDialect` spelling table. `HostOp::Launch` / `HostOp::Bfs` no longer
//! carry AST; renderers never see `dsl::ast::Stmt` at all.

use crate::dsl::ast::{Expr, IterSource, LValue, MinMax, ReduceOp, Stmt};
use crate::ir::analyze::as_reduction;
use crate::ir::plan::PropTable;
use crate::ir::ScalarTy;
use crate::sema::TypedFunction;

/// Which sweep of `iterateInBFS` a neighbor loop sits in. Both directions
/// restrict neighbor iteration to BFS-DAG children (`level[nbr] ==
/// level[v] + 1`); the reverse sweep walks the *vertices* backwards by level
/// (host loop), not the edges, so the per-edge filter is shared.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BfsDir {
    Forward,
    Reverse,
}

/// The device cell an atomic reduction lands in.
#[derive(Clone, Debug, PartialEq)]
pub enum KCell {
    /// one element of a property buffer: `dist[nbr]`
    Prop { slot: u32, obj: String },
    /// a single-word scalar reduction cell (`d_diff`, `d_triangle_count`)
    Cell { name: String },
}

/// An assignment target inside a kernel (Min/Max extras, plain stores).
#[derive(Clone, Debug, PartialEq)]
pub enum KTarget {
    Var(String),
    Prop { slot: u32, obj: String },
}

/// One backend-neutral device-side operation. Expressions stay as
/// [`Expr`] trees (spelled per backend by `codegen::cexpr`); everything
/// *structural* — loop shape, guards, atomicity, types, slots — is resolved
/// here, once.
#[derive(Clone, Debug, PartialEq)]
pub enum KernelOp {
    /// kernel-local declaration (`int e = edge;`, `float sum = 0.0;`)
    Decl { name: String, ty: ScalarTy, init: Option<Expr> },
    /// plain scalar store
    AssignVar { name: String, value: Expr },
    /// plain property store (`level[w] = level[v] + 1`)
    AssignProp { slot: u32, obj: String, value: Expr },
    /// atomic reduction into a cell, tagged with the value's machine type
    /// (drives float-atomics emulation on backends without them, §3.3)
    Reduce { cell: KCell, op: ReduceOp, ty: ScalarTy, value: Expr },
    /// §3.5 Min/Max construct: compare-and-update one property element plus
    /// extra stores applied only when the Min/Max wins; `or_flag` marks that
    /// a win also clears the enclosing fixedPoint's convergence flag (§4.1)
    MinMax {
        kind: MinMax,
        slot: u32,
        obj: String,
        ty: ScalarTy,
        compare: Expr,
        extra: Vec<(KTarget, Expr)>,
        or_flag: bool,
    },
    /// CSR (`reverse: false`) or reverse-CSR (`reverse: true`) neighbor scan.
    /// `bfs` restricts iteration to BFS-DAG children (§3.4); `filter` is the
    /// `.filter(...)` guard, already resolved against the loop variable.
    NeighborLoop {
        var: String,
        of: String,
        reverse: bool,
        bfs: Option<BfsDir>,
        filter: Option<Expr>,
        body: Vec<KernelOp>,
    },
    If { cond: Expr, then: Vec<KernelOp>, els: Option<Vec<KernelOp>> },
    /// construct no device backend supports (rendered as a comment)
    Unsupported { what: String },
}

impl KernelOp {
    /// Depth-first visit of this op and everything nested under it.
    pub fn visit(&self, f: &mut impl FnMut(&KernelOp)) {
        f(self);
        match self {
            KernelOp::NeighborLoop { body, .. } => {
                for op in body {
                    op.visit(f);
                }
            }
            KernelOp::If { then, els, .. } => {
                for op in then {
                    op.visit(f);
                }
                if let Some(e) = els {
                    for op in e {
                        op.visit(f);
                    }
                }
            }
            _ => {}
        }
    }
}

/// The complete lowered body of one device kernel: the thread-index variable
/// the surrounding emitter binds, the forall's own `.filter(...)` guard
/// (resolved and simplified — the thread early-out), and the op tree.
#[derive(Clone, Debug, PartialEq)]
pub struct KernelBody {
    pub thread_var: String,
    pub guard: Option<Expr>,
    pub ops: Vec<KernelOp>,
}

impl KernelBody {
    /// Property slots this body updates atomically (Reduce / MinMax
    /// targets), sorted. Dialects with typed atomics (Metal's `atomic_int`
    /// buffers, WGSL's `array<atomic<i32>>`) declare these differently and
    /// wrap their plain reads in atomic loads.
    pub fn atomic_prop_slots(&self) -> Vec<u32> {
        let mut out = Vec::new();
        for op in &self.ops {
            op.visit(&mut |o| match o {
                KernelOp::Reduce { cell: KCell::Prop { slot, .. }, .. } => out.push(*slot),
                KernelOp::MinMax { slot, .. } => out.push(*slot),
                _ => {}
            });
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// Does `e` reference properties only at `obj` (and otherwise only scalars,
/// literals, and pure operators)? The conservative admissibility check for
/// re-orienting a relaxation: anything else (neighbor-indexed reads, edge
/// lookups, calls) pins the body to its compiled direction.
fn refs_props_only_at(e: &Expr, obj: &str) -> bool {
    match e {
        Expr::Prop { obj: o, .. } => o == obj,
        Expr::Unary { expr, .. } => refs_props_only_at(expr, obj),
        Expr::Binary { lhs, rhs, .. } => {
            refs_props_only_at(lhs, obj) && refs_props_only_at(rhs, obj)
        }
        Expr::Var(_) | Expr::IntLit(_) | Expr::FloatLit(_) | Expr::BoolLit(_) | Expr::Inf => true,
        _ => false,
    }
}

/// Rewrite property accesses on `from` to accesses on `to` (the push→pull
/// re-orientation: the relaxation source moves from the thread vertex to the
/// reverse-loop variable).
fn retarget_props(e: &Expr, from: &str, to: &str) -> Expr {
    match e {
        Expr::Prop { obj, prop } if obj == from => {
            Expr::Prop { obj: to.to_string(), prop: prop.clone() }
        }
        Expr::Unary { op, expr } => {
            Expr::Unary { op: *op, expr: Box::new(retarget_props(expr, from, to)) }
        }
        Expr::Binary { op, lhs, rhs } => Expr::Binary {
            op: *op,
            lhs: Box::new(retarget_props(lhs, from, to)),
            rhs: Box::new(retarget_props(rhs, from, to)),
        },
        other => other.clone(),
    }
}

/// Derive the **pull variant** of a push-relaxation kernel body, or `None`
/// when the body is not mechanically re-orientable.
///
/// The push shape `for w in neighbors(v): MinMax(dist[w], f(v)) + marks` is
/// rewritten to `for w in nodes_to(v) [if guard(w)]: MinMax(dist[v], f(w)) +
/// marks on v` — same edges visited, each relaxation landing on the thread's
/// own vertex, with the old thread guard becoming the reverse-loop filter.
/// Admissible only when the compare and guard read properties at the thread
/// vertex alone and every extra update stores a literal to the neighbor:
/// notably a *weighted* relaxation (SSSP's `e.weight`) is NOT derivable,
/// because device buffers carry no `rev_edge_id` map from a reverse slot
/// back to its forward edge — the interpreter pulls weighted relaxations,
/// generated kernels cannot.
pub fn pull_variant(body: &KernelBody) -> Option<KernelBody> {
    let tv = body.thread_var.as_str();
    let [KernelOp::NeighborLoop { var, of, reverse: false, bfs: None, filter: None, body: inner }] =
        &body.ops[..]
    else {
        return None;
    };
    if of != tv {
        return None;
    }
    let [KernelOp::MinMax { kind, slot, obj, ty, compare, extra, or_flag }] = &inner[..] else {
        return None;
    };
    if obj != var || !refs_props_only_at(compare, tv) {
        return None;
    }
    if let Some(g) = &body.guard {
        if !refs_props_only_at(g, tv) {
            return None;
        }
    }
    let extra_ok = extra.iter().all(|(t, v)| {
        matches!(t, KTarget::Prop { obj, .. } if obj == var)
            && matches!(v, Expr::IntLit(_) | Expr::FloatLit(_) | Expr::BoolLit(_))
    });
    if !extra_ok {
        return None;
    }
    let pulled = KernelOp::MinMax {
        kind: *kind,
        slot: *slot,
        obj: tv.to_string(),
        ty: *ty,
        compare: retarget_props(compare, tv, var),
        extra: extra
            .iter()
            .map(|(t, v)| {
                let KTarget::Prop { slot, .. } = t else { unreachable!() };
                (KTarget::Prop { slot: *slot, obj: tv.to_string() }, v.clone())
            })
            .collect(),
        or_flag: *or_flag,
    };
    Some(KernelBody {
        thread_var: body.thread_var.clone(),
        guard: None,
        ops: vec![KernelOp::NeighborLoop {
            var: var.clone(),
            of: tv.to_string(),
            reverse: true,
            bfs: None,
            filter: body.guard.as_ref().map(|g| retarget_props(g, tv, var)),
            body: vec![pulled],
        }],
    })
}

/// Context for one kernel-body lowering.
pub(crate) struct KernelLower<'a> {
    pub tf: &'a TypedFunction,
    pub props: &'a PropTable,
    /// inside iterateInBFS / iterateInReverse (adds the §3.4 level filter)
    pub bfs: Option<BfsDir>,
    /// launch site sits inside a fixedPoint: Min/Max wins clear the OR-flag
    pub or_flag: bool,
}

/// Lower one kernel body to [`KernelOp`]s. Called exactly once per kernel,
/// from the plan's host walk (which knows the fixedPoint / BFS context).
pub(crate) fn lower_kernel_body(body: &[Stmt], cx: &KernelLower<'_>) -> Vec<KernelOp> {
    body.iter().map(|s| lower_stmt(s, cx)).collect()
}

fn prop_slot(cx: &KernelLower<'_>, prop: &str) -> Option<u32> {
    cx.props.slot(prop)
}

fn prop_ty(cx: &KernelLower<'_>, slot: u32) -> ScalarTy {
    cx.props.meta(slot).ty
}

fn var_ty(cx: &KernelLower<'_>, name: &str) -> ScalarTy {
    // the I64 fallback matches the plan's reduction-cell typing
    cx.tf.vars.get(name).map(ScalarTy::of).unwrap_or(ScalarTy::I64)
}

fn lower_target(cx: &KernelLower<'_>, t: &LValue) -> Option<KTarget> {
    match t {
        LValue::Var(v) => Some(KTarget::Var(v.clone())),
        LValue::Prop { obj, prop } => {
            prop_slot(cx, prop).map(|slot| KTarget::Prop { slot, obj: obj.clone() })
        }
    }
}

fn lower_reduce(cx: &KernelLower<'_>, target: &LValue, op: ReduceOp, value: &Expr) -> KernelOp {
    match target {
        LValue::Var(v) => KernelOp::Reduce {
            cell: KCell::Cell { name: v.clone() },
            op,
            ty: var_ty(cx, v),
            value: value.clone(),
        },
        LValue::Prop { obj, prop } => match prop_slot(cx, prop) {
            Some(slot) => KernelOp::Reduce {
                cell: KCell::Prop { slot, obj: obj.clone() },
                op,
                ty: prop_ty(cx, slot),
                value: value.clone(),
            },
            None => KernelOp::Unsupported { what: format!("reduction into unknown `{prop}`") },
        },
    }
}

fn lower_stmt(s: &Stmt, cx: &KernelLower<'_>) -> KernelOp {
    match s {
        Stmt::Decl { ty, name, init, .. } => {
            KernelOp::Decl { name: name.clone(), ty: ScalarTy::of(ty), init: init.clone() }
        }
        Stmt::Assign { target, value, .. } => {
            // `x = x + e` on a *property* is an atomic reduction in disguise;
            // scalar accumulators (`sum = sum + ...`) stay plain stores
            if let Some((t, op, rhs)) = as_reduction(target, value) {
                if matches!(t, LValue::Prop { .. }) {
                    return lower_reduce(cx, &t, op, &rhs);
                }
            }
            match target {
                LValue::Var(v) => KernelOp::AssignVar { name: v.clone(), value: value.clone() },
                LValue::Prop { obj, prop } => match prop_slot(cx, prop) {
                    Some(slot) => KernelOp::AssignProp {
                        slot,
                        obj: obj.clone(),
                        value: value.clone(),
                    },
                    None => {
                        KernelOp::Unsupported { what: format!("store to unknown `{prop}`") }
                    }
                },
            }
        }
        Stmt::Reduce { target, op, value, .. } => lower_reduce(cx, target, *op, value),
        Stmt::MinMaxAssign { kind, target, compare, extra, .. } => {
            let LValue::Prop { obj, prop } = target else {
                return KernelOp::Unsupported { what: "Min/Max on scalars".to_string() };
            };
            let Some(slot) = prop_slot(cx, prop) else {
                return KernelOp::Unsupported { what: format!("Min/Max on unknown `{prop}`") };
            };
            let extra = extra
                .iter()
                .filter_map(|(t, v)| lower_target(cx, t).map(|t| (t, v.clone())))
                .collect();
            KernelOp::MinMax {
                kind: *kind,
                slot,
                obj: obj.clone(),
                ty: prop_ty(cx, slot),
                compare: compare.clone(),
                extra,
                or_flag: cx.or_flag,
            }
        }
        Stmt::For { iter, body, .. } => {
            let (of, reverse) = match &iter.source {
                IterSource::Neighbors { of, .. } => (of.clone(), false),
                IterSource::NodesTo { of, .. } => (of.clone(), true),
                IterSource::Nodes { .. } | IterSource::Set { .. } => {
                    return KernelOp::Unsupported {
                        what: "nested full-graph iteration".to_string(),
                    }
                }
            };
            let filter = iter
                .filter
                .as_ref()
                .map(|f| simplify_bool_cmp(&resolve_filter(f, &iter.var, cx.tf)));
            // the reverse sweep's edge filter is the forward one: both walk
            // BFS-DAG children; only the host-side level order flips (§3.4)
            KernelOp::NeighborLoop {
                var: iter.var.clone(),
                of,
                reverse,
                bfs: cx.bfs,
                filter,
                body: lower_kernel_body(body, cx),
            }
        }
        Stmt::If { cond, then, els, .. } => KernelOp::If {
            cond: cond.clone(),
            then: lower_kernel_body(then, cx),
            els: els.as_ref().map(|e| lower_kernel_body(e, cx)),
        },
        other => KernelOp::Unsupported {
            what: format!("{:?}", std::mem::discriminant(other)),
        },
    }
}

/// Resolve bare property names in filter expressions to explicit
/// `loopVar.prop` accesses (the StarPlat `filter(modified == True)` idiom).
pub fn resolve_filter(e: &Expr, var: &str, tf: &TypedFunction) -> Expr {
    match e {
        Expr::Var(name) if tf.node_props.contains_key(name) => {
            Expr::Prop { obj: var.to_string(), prop: name.clone() }
        }
        Expr::Unary { op, expr } => {
            Expr::Unary { op: *op, expr: Box::new(resolve_filter(expr, var, tf)) }
        }
        Expr::Binary { op, lhs, rhs } => Expr::Binary {
            op: *op,
            lhs: Box::new(resolve_filter(lhs, var, tf)),
            rhs: Box::new(resolve_filter(rhs, var, tf)),
        },
        Expr::Call { recv, name, args } => Expr::Call {
            recv: recv.clone(),
            name: name.clone(),
            args: args.iter().map(|a| resolve_filter(a, var, tf)).collect(),
        },
        other => other.clone(),
    }
}

/// Normalize boolean comparisons for C output, with the literal on either
/// side: `x == True` / `True == x` → `x`, `x == False` / `False == x` → `!x`
/// (cleaner generated code, as in the paper's figures). `!=` flips the sense.
pub fn simplify_bool_cmp(e: &Expr) -> Expr {
    use crate::dsl::ast::{BinOp, UnOp};
    if let Expr::Binary { op, lhs, rhs } = e {
        let (lit, other) = match (&**lhs, &**rhs) {
            (_, Expr::BoolLit(b)) => (Some(*b), lhs),
            (Expr::BoolLit(b), _) => (Some(*b), rhs),
            _ => (None, lhs),
        };
        let want = match (op, lit) {
            (BinOp::Eq, Some(b)) => Some(b),
            (BinOp::Ne, Some(b)) => Some(!b),
            _ => None,
        };
        if let Some(w) = want {
            return if w {
                (**other).clone()
            } else {
                Expr::Unary { op: UnOp::Not, expr: other.clone() }
            };
        }
    }
    e.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse;
    use crate::sema::check_function;

    fn lowered(program: &str) -> (TypedFunction, PropTable) {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("dsl_programs")
            .join(program);
        let src = std::fs::read_to_string(&path).unwrap();
        let fns = parse(&src).unwrap();
        let tf = check_function(&fns[0]).unwrap();
        let props = PropTable::build(&tf);
        (tf, props)
    }

    /// The forall body of the first parallel loop found under `body`.
    fn first_forall(body: &[Stmt]) -> &Stmt {
        for s in body {
            match s {
                Stmt::For { parallel: true, .. } => return s,
                Stmt::FixedPoint { body, .. }
                | Stmt::DoWhile { body, .. }
                | Stmt::While { body, .. } => return first_forall(body),
                _ => {}
            }
        }
        panic!("no forall found");
    }

    #[test]
    fn sssp_relax_lowers_to_minmax_with_or_flag() {
        let (tf, props) = lowered("sssp.sp");
        let Stmt::For { body, .. } = first_forall(&tf.func.body) else { unreachable!() };
        let cx = KernelLower { tf: &tf, props: &props, bfs: None, or_flag: true };
        let ops = lower_kernel_body(body, &cx);
        // one neighbor loop, containing the edge decl + Min construct
        let [KernelOp::NeighborLoop { var, of, reverse, bfs, filter, body }] = &ops[..] else {
            panic!("expected a single neighbor loop, got {ops:?}");
        };
        assert_eq!((var.as_str(), of.as_str()), ("nbr", "v"));
        assert!(!reverse && bfs.is_none() && filter.is_none());
        assert!(matches!(&body[0], KernelOp::Decl { name, ty: ScalarTy::I32, .. } if name == "e"));
        let KernelOp::MinMax { kind, slot, obj, ty, extra, or_flag, .. } = &body[1] else {
            panic!("expected MinMax, got {:?}", body[1]);
        };
        assert_eq!(*kind, MinMax::Min);
        assert_eq!(*slot, props.slot("dist").unwrap());
        assert_eq!(obj, "nbr");
        assert_eq!(*ty, ScalarTy::I32);
        assert!(*or_flag, "fixedPoint context must mark the OR-flag clear");
        assert!(matches!(
            &extra[..],
            [(KTarget::Prop { slot, obj }, Expr::BoolLit(true))]
                if *slot == props.slot("modified_nxt").unwrap() && obj == "nbr"
        ));
    }

    #[test]
    fn tc_counts_into_a_scalar_cell_and_filters_resolve() {
        let (tf, props) = lowered("tc.sp");
        let Stmt::For { body, .. } = first_forall(&tf.func.body) else { unreachable!() };
        let cx = KernelLower { tf: &tf, props: &props, bfs: None, or_flag: false };
        let ops = lower_kernel_body(body, &cx);
        let KernelOp::NeighborLoop { filter, body: inner, .. } = &ops[0] else {
            panic!("expected neighbor loop");
        };
        assert!(filter.is_some(), "u < v filter survives lowering");
        let KernelOp::NeighborLoop { body: inner2, .. } = &inner[0] else {
            panic!("expected nested neighbor loop");
        };
        let KernelOp::If { then, .. } = &inner2[0] else { panic!("expected is_an_edge guard") };
        assert!(matches!(
            &then[0],
            KernelOp::Reduce { cell: KCell::Cell { name }, op: ReduceOp::Add, ty: ScalarTy::I64, .. }
                if name == "triangle_count"
        ));
    }

    #[test]
    fn pr_scalar_accumulator_stays_a_plain_store() {
        let (tf, props) = lowered("pr.sp");
        let Stmt::For { body, .. } = first_forall(&tf.func.body) else { unreachable!() };
        let cx = KernelLower { tf: &tf, props: &props, bfs: None, or_flag: false };
        let ops = lower_kernel_body(body, &cx);
        // float sum = 0.0; then the reverse-CSR pull loop with sum = sum + ...
        assert!(matches!(&ops[0], KernelOp::Decl { name, .. } if name == "sum"));
        let KernelOp::NeighborLoop { reverse, body: inner, .. } = &ops[1] else {
            panic!("expected pull loop, got {:?}", ops[1]);
        };
        assert!(*reverse, "nodes_to iterates the reverse CSR");
        assert!(
            matches!(&inner[0], KernelOp::AssignVar { name, .. } if name == "sum"),
            "scalar accumulation must not become an atomic reduction"
        );
        // diff += abs(...) is a real reduction into the diff cell
        let has_diff = ops.iter().any(|o| {
            matches!(o, KernelOp::Reduce { cell: KCell::Cell { name }, op: ReduceOp::Add, ty: ScalarTy::F32, .. } if name == "diff")
        });
        assert!(has_diff);
    }

    #[test]
    fn bfs_context_marks_neighbor_loops_and_atomic_slots() {
        let (tf, props) = lowered("bc.sp");
        // forward BFS body: forall (w in g.neighbors(v)) { w.sigma += v.sigma; }
        let bfs_body = tf
            .func
            .body
            .iter()
            .find_map(|s| match s {
                Stmt::For { body, .. } => body.iter().find_map(|s| match s {
                    Stmt::IterateBFS { body, .. } => Some(body),
                    _ => None,
                }),
                _ => None,
            })
            .expect("bc has an iterateInBFS");
        let cx =
            KernelLower { tf: &tf, props: &props, bfs: Some(BfsDir::Forward), or_flag: false };
        let ops = lower_kernel_body(bfs_body, &cx);
        let KernelOp::NeighborLoop { bfs, body, .. } = &ops[0] else {
            panic!("expected neighbor loop");
        };
        assert_eq!(*bfs, Some(BfsDir::Forward));
        assert!(matches!(
            &body[0],
            KernelOp::Reduce { cell: KCell::Prop { slot, obj }, op: ReduceOp::Add, .. }
                if *slot == props.slot("sigma").unwrap() && obj == "w"
        ));
        let kb = KernelBody { thread_var: "v".into(), guard: None, ops };
        assert_eq!(kb.atomic_prop_slots(), vec![props.slot("sigma").unwrap()]);
    }

    #[test]
    fn cc_relax_has_a_pull_variant() {
        let (tf, props) = lowered("cc.sp");
        let Stmt::For { body, .. } = first_forall(&tf.func.body) else { unreachable!() };
        let cx = KernelLower { tf: &tf, props: &props, bfs: None, or_flag: true };
        let ops = lower_kernel_body(body, &cx);
        let guard = Expr::Prop { obj: "v".into(), prop: "modified".into() };
        let push = KernelBody { thread_var: "v".into(), guard: Some(guard), ops };
        let pull = pull_variant(&push).expect("weight-free relax is re-orientable");
        assert!(pull.guard.is_none(), "pull scans every vertex; the guard moves inward");
        let [KernelOp::NeighborLoop { var, of, reverse, bfs, filter, body }] = &pull.ops[..]
        else {
            panic!("expected a single reverse loop, got {:?}", pull.ops);
        };
        assert_eq!((var.as_str(), of.as_str()), ("nbr", "v"));
        assert!(*reverse && bfs.is_none());
        assert!(
            matches!(filter, Some(Expr::Prop { obj, prop }) if obj == "nbr" && prop == "modified"),
            "thread guard becomes an in-neighbor filter, got {filter:?}"
        );
        let [KernelOp::MinMax { kind, slot, obj, compare, extra, or_flag, .. }] = &body[..]
        else {
            panic!("expected a single MinMax, got {body:?}");
        };
        assert_eq!(*kind, MinMax::Min);
        assert_eq!(*slot, props.slot("comp").unwrap());
        assert_eq!(obj, "v", "pull relaxes into the thread's own vertex");
        assert!(
            matches!(compare, Expr::Prop { obj, prop } if obj == "nbr" && prop == "comp"),
            "compare reads the in-neighbor, got {compare:?}"
        );
        assert!(*or_flag);
        assert!(matches!(
            &extra[..],
            [(KTarget::Prop { slot, obj }, Expr::BoolLit(true))]
                if *slot == props.slot("modified_nxt").unwrap() && obj == "v"
        ));
    }

    #[test]
    fn weighted_relax_has_no_pull_variant() {
        // SSSP's compare reads e.weight through a forward edge id; device
        // buffers carry no rev_edge_id, so the body must stay push-only.
        let (tf, props) = lowered("sssp.sp");
        let Stmt::For { body, .. } = first_forall(&tf.func.body) else { unreachable!() };
        let cx = KernelLower { tf: &tf, props: &props, bfs: None, or_flag: true };
        let ops = lower_kernel_body(body, &cx);
        let push = KernelBody { thread_var: "v".into(), guard: None, ops };
        assert!(pull_variant(&push).is_none());
    }

    #[test]
    fn pull_variant_rejects_filtered_and_reverse_loops() {
        let (tf, props) = lowered("cc.sp");
        let Stmt::For { body, .. } = first_forall(&tf.func.body) else { unreachable!() };
        let cx = KernelLower { tf: &tf, props: &props, bfs: None, or_flag: true };
        let ops = lower_kernel_body(body, &cx);
        let mut filtered = KernelBody { thread_var: "v".into(), guard: None, ops };
        let KernelOp::NeighborLoop { filter, .. } = &mut filtered.ops[0] else { unreachable!() };
        *filter = Some(Expr::BoolLit(true));
        assert!(pull_variant(&filtered).is_none(), "an existing filter pins the direction");
        let KernelOp::NeighborLoop { filter, reverse, .. } = &mut filtered.ops[0] else {
            unreachable!()
        };
        *filter = None;
        *reverse = true;
        assert!(pull_variant(&filtered).is_none(), "already-pull bodies are not re-derived");
    }
}
