//! Variable-use analysis over DSL blocks.
//!
//! Feeds the paper's §4 optimizations: which properties/scalars a kernel
//! reads (→ copy-in), writes (→ copy-out), and which scalar reductions it
//! performs (→ atomics / reduction clauses).

use crate::dsl::ast::*;
use std::collections::BTreeSet;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct VarUse {
    /// scalar (host) variables read inside the region
    pub scalars_read: BTreeSet<String>,
    /// scalar variables written by plain assignment (rare inside kernels;
    /// usually forall-local temporaries)
    pub scalars_written: BTreeSet<String>,
    /// node/edge property names read
    pub props_read: BTreeSet<String>,
    /// node/edge property names written
    pub props_written: BTreeSet<String>,
    /// scalar reductions `(target, op)` — need atomics on the device
    pub reductions: Vec<(String, ReduceOp)>,
    /// variables declared locally inside the region (device-only, §4.1)
    pub locals: BTreeSet<String>,
    /// does the region call `g.is_an_edge` (TC) — needs the CSR on device
    pub uses_is_an_edge: bool,
    /// does the region iterate `g.nodes_to(..)` — needs reverse CSR
    pub uses_in_edges: bool,
    /// does the region use edge weights via `propEdge` access
    pub uses_weights: bool,
}

impl VarUse {
    fn expr(&mut self, e: &Expr) {
        match e {
            Expr::Var(v) => {
                if !self.locals.contains(v) {
                    self.scalars_read.insert(v.clone());
                }
            }
            Expr::Prop { obj, prop } => {
                self.props_read.insert(prop.clone());
                if !self.locals.contains(obj) {
                    self.scalars_read.insert(obj.clone());
                }
            }
            Expr::Call { recv, name, args } => {
                if name == "is_an_edge" {
                    self.uses_is_an_edge = true;
                }
                if let Some(r) = recv {
                    if !self.locals.contains(r) {
                        self.scalars_read.insert(r.clone());
                    }
                }
                for a in args {
                    self.expr(a);
                }
            }
            Expr::Unary { expr, .. } => self.expr(expr),
            Expr::Binary { lhs, rhs, .. } => {
                self.expr(lhs);
                self.expr(rhs);
            }
            _ => {}
        }
    }

    fn lvalue_write(&mut self, lv: &LValue) {
        match lv {
            LValue::Var(v) => {
                if !self.locals.contains(v) {
                    self.scalars_written.insert(v.clone());
                }
            }
            LValue::Prop { obj, prop } => {
                self.props_written.insert(prop.clone());
                if !self.locals.contains(obj) {
                    self.scalars_read.insert(obj.clone());
                }
            }
        }
    }

    fn stmt(&mut self, s: &Stmt) {
        match s {
            Stmt::Decl { name, init, .. } => {
                if let Some(e) = init {
                    self.expr(e);
                }
                self.locals.insert(name.clone());
            }
            Stmt::Assign { target, value, .. } => {
                self.expr(value);
                self.lvalue_write(target);
            }
            Stmt::Reduce { target, op, value, .. } => {
                self.expr(value);
                match target {
                    LValue::Var(v) if !self.locals.contains(v) => {
                        self.reductions.push((v.clone(), *op));
                        self.scalars_read.insert(v.clone());
                    }
                    _ => {
                        // property reductions behave like read-modify-write
                        if let LValue::Prop { prop, .. } = target {
                            self.props_read.insert(prop.clone());
                        }
                        self.lvalue_write(target);
                    }
                }
            }
            Stmt::MinMaxAssign { target, compare, extra, .. } => {
                self.expr(compare);
                if let LValue::Prop { prop, .. } = target {
                    self.props_read.insert(prop.clone());
                }
                self.lvalue_write(target);
                for (t, v) in extra {
                    self.expr(v);
                    self.lvalue_write(t);
                }
            }
            Stmt::AttachNodeProperty { inits, .. } => {
                for (p, e) in inits {
                    self.expr(e);
                    self.props_written.insert(p.clone());
                }
            }
            Stmt::For { iter, body, .. } => {
                self.locals.insert(iter.var.clone());
                match &iter.source {
                    IterSource::Neighbors { of, .. } => {
                        if !self.locals.contains(of) {
                            self.scalars_read.insert(of.clone());
                        }
                    }
                    IterSource::NodesTo { of, .. } => {
                        self.uses_in_edges = true;
                        if !self.locals.contains(of) {
                            self.scalars_read.insert(of.clone());
                        }
                    }
                    _ => {}
                }
                if let Some(f) = &iter.filter {
                    self.filter_expr(f);
                }
                for st in body {
                    self.stmt(st);
                }
            }
            Stmt::IterateBFS { var, from, body, reverse, .. } => {
                self.locals.insert(var.clone());
                self.scalars_read.insert(from.clone());
                for st in body {
                    self.stmt(st);
                }
                if let Some((cond, rbody)) = reverse {
                    self.filter_expr(cond);
                    for st in rbody {
                        self.stmt(st);
                    }
                }
            }
            Stmt::FixedPoint { body, cond, .. } => {
                self.filter_expr(cond);
                for st in body {
                    self.stmt(st);
                }
            }
            Stmt::DoWhile { body, cond, .. } | Stmt::While { cond, body, .. } => {
                self.expr(cond);
                for st in body {
                    self.stmt(st);
                }
            }
            Stmt::If { cond, then, els, .. } => {
                self.expr(cond);
                for st in then {
                    self.stmt(st);
                }
                if let Some(e) = els {
                    for st in e {
                        self.stmt(st);
                    }
                }
            }
            Stmt::Return { value, .. } => self.expr(value),
        }
    }

    /// Filter expressions reference properties by bare name (implicit loop
    /// variable): record those as property *reads*, not scalar reads.
    fn filter_expr(&mut self, e: &Expr) {
        match e {
            Expr::Var(v) => {
                // conservatively record as both; `transfer::plan` reclassifies
                // using the property registry.
                self.props_read.insert(v.clone());
            }
            Expr::Unary { expr, .. } => self.filter_expr(expr),
            Expr::Binary { lhs, rhs, .. } => {
                self.filter_expr(lhs);
                self.filter_expr(rhs);
            }
            other => self.expr(other),
        }
    }
}

/// Recognize the read-modify-write idiom `x.p = x.p + e` (or `*`, `&&`,
/// `||`) as a reduction — StarPlat generates atomics for these (e.g. the
/// sigma accumulation in BC's forward pass). Returns `(target, op, rhs)`.
pub fn as_reduction(target: &LValue, value: &Expr) -> Option<(LValue, ReduceOp, Expr)> {
    let Expr::Binary { op, lhs, rhs } = value else { return None };
    let red = match op {
        BinOp::Add => ReduceOp::Add,
        BinOp::Mul => ReduceOp::Mul,
        BinOp::And => ReduceOp::And,
        BinOp::Or => ReduceOp::Or,
        _ => return None,
    };
    let matches_target = |e: &Expr| match (e, target) {
        (Expr::Var(v), LValue::Var(t)) => v == t,
        (Expr::Prop { obj, prop }, LValue::Prop { obj: to, prop: tp }) => obj == to && prop == tp,
        _ => false,
    };
    if matches_target(lhs) {
        Some((target.clone(), red, (**rhs).clone()))
    } else if matches_target(rhs) && matches!(red, ReduceOp::Add | ReduceOp::Mul) {
        Some((target.clone(), red, (**lhs).clone()))
    } else {
        None
    }
}

pub fn block_uses(b: &[Stmt]) -> VarUse {
    let mut u = VarUse::default();
    for s in b {
        u.stmt(s);
    }
    u
}

pub fn stmt_uses(s: &Stmt) -> VarUse {
    let mut u = VarUse::default();
    u.stmt(s);
    u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse;

    fn body_of(src: &str) -> Vec<Stmt> {
        parse(src).unwrap().remove(0).body
    }

    #[test]
    fn reads_writes_and_reductions() {
        let body = body_of(
            "function f(Graph g, propNode<int> dist, propEdge<int> weight) {
               long c = 0;
               forall (v in g.nodes()) {
                 int local = 1;
                 forall (nbr in g.neighbors(v)) {
                   edge e = g.get_edge(v, nbr);
                   nbr.dist = v.dist + e.weight;
                   c += local;
                 }
               }
             }",
        );
        let Stmt::For { body: fb, .. } = &body[1] else { panic!() };
        let u = block_uses(fb);
        assert!(u.props_read.contains("dist"));
        assert!(u.props_read.contains("weight"));
        assert!(u.props_written.contains("dist"));
        assert!(!u.props_written.contains("weight"));
        assert_eq!(u.reductions, vec![("c".to_string(), ReduceOp::Add)]);
        assert!(u.locals.contains("local"));
        assert!(u.locals.contains("nbr"));
        // v is the outer kernel's loop var: here it's local to the analyzed
        // block only if declared by it — the outer forall declares it.
        assert!(!u.scalars_read.contains("local"));
    }

    #[test]
    fn is_an_edge_and_in_edges_flags() {
        let body = body_of(
            "function f(Graph g, propNode<float> pr) {
               forall (v in g.nodes()) {
                 float s = 0;
                 for (nbr in g.nodes_to(v)) { s = s + nbr.pr; }
                 if (g.is_an_edge(v, v)) { s = s + 1; }
               }
             }",
        );
        let Stmt::For { body: fb, .. } = &body[0] else { panic!() };
        let u = block_uses(fb);
        assert!(u.uses_in_edges);
        assert!(u.uses_is_an_edge);
    }

    #[test]
    fn filter_props_are_prop_reads() {
        let body = body_of(
            "function f(Graph g, propNode<bool> modified) {
               forall (v in g.nodes().filter(modified == True)) { }
             }",
        );
        let u = stmt_uses(&body[0]);
        assert!(u.props_read.contains("modified"));
        assert!(!u.scalars_read.contains("modified"));
    }
}
