//! Backend-neutral device plan: the single lowering layer between the IR and
//! every accelerator renderer.
//!
//! # Pipeline
//!
//! ```text
//! AST (dsl::ast) ──sema──▶ TypedFunction ──ir::lower──▶ IrProgram
//!                                                          │
//!                                          DevicePlan::build (this module)
//!                                                          │
//!                    ┌───────────────┬────────────┬────────┴───┬───────────┐
//!                    ▼               ▼            ▼            ▼           ▼
//!              codegen::cuda  codegen::opencl codegen::sycl codegen::openacc
//!                    └───────────────┴────────────┴────────────┘      codegen::jax
//!                                 (thin renderers: syntax only)
//! ```
//!
//! The paper's core claim (§3) is one algorithmic specification feeding CUDA,
//! OpenCL, SYCL, and OpenACC generators. Before this layer existed, each of
//! the four text emitters re-derived function parameters, device-buffer sets,
//! property C types, and kernel numbering independently from the AST — four
//! copies of the same analysis. The [`DevicePlan`] resolves all of that once:
//!
//! - **buffers**: every node/edge property gets a stable slot from the same
//!   [`PropTable`] the interpreter's lowering uses ([`crate::backends::interp::compile`]
//!   calls [`PropTable::build`] too), so interpreter and codegen agree on
//!   numbering *by construction*;
//! - **types**: scalar machine types are mapped per backend through a
//!   [`TypeMap`] hook (e.g. OpenCL has no device-side `bool` arrays, so its
//!   map sends `Bool` to `int`) — resolved here, not in emitters;
//! - **kernel schedule**: one [`KernelPlan`] per IR kernel, carrying its name,
//!   its parameter list in interner (slot) order, and the bound §4 transfer
//!   steps (graph CSR H2D once; property copy-ins owed before first launch;
//!   outputs-only D2H, deferred past convergence loops);
//! - **host-loop skeletons**: [`FixedPointPlan`] (Fig 12's device-flag
//!   ping-pong) and [`BfsPlan`] (Fig 9's level-synchronous do-while) in
//!   program order, consumed by renderers through a [`PlanCursor`].
//!
//! A renderer walks the AST only for *statement syntax* (expressions, loop
//! shapes); everything that is an analysis result comes from the plan. Every
//! renderer also embeds [`DevicePlan::manifest`] as a comment block, which is
//! byte-identical across backends — `tests/plan_numbering.rs` snapshots it to
//! pin the cross-backend numbering guarantee.

use crate::dsl::ast::{ReduceOp, Stmt, Type};
use crate::ir::slots::Interner;
use crate::ir::{IrProgram, Kernel, KernelKind, ScalarTy};
use crate::sema::TypedFunction;

// ---------------------------------------------------------------------------
// Per-backend type mapping
// ---------------------------------------------------------------------------

/// Scalar-type spelling for one backend. The hooks live here so a backend's
/// quirks (OpenCL's missing device `bool`, numpy dtype names) are resolved in
/// one place instead of inside each emitter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TypeMap {
    pub int: &'static str,
    pub long: &'static str,
    pub float: &'static str,
    pub double: &'static str,
    pub boolean: &'static str,
}

impl TypeMap {
    /// C / C++ family (CUDA, SYCL, OpenACC, and every host half).
    pub const C: TypeMap = TypeMap {
        int: "int",
        long: "long long",
        float: "float",
        double: "double",
        boolean: "bool",
    };
    /// OpenCL C device code: no `bool` arrays (§3), 64-bit int is `long`.
    pub const OPENCL: TypeMap = TypeMap {
        int: "int",
        long: "long",
        float: "float",
        double: "double",
        boolean: "int",
    };
    /// numpy dtype names, for the JAX backend's buffer bindings.
    pub const NUMPY: TypeMap = TypeMap {
        int: "int32",
        long: "int64",
        float: "float32",
        double: "float64",
        boolean: "bool_",
    };

    pub fn name(&self, t: ScalarTy) -> &'static str {
        match t {
            ScalarTy::I32 => self.int,
            ScalarTy::I64 => self.long,
            ScalarTy::F32 => self.float,
            ScalarTy::F64 => self.double,
            ScalarTy::Bool => self.boolean,
        }
    }
}

// ---------------------------------------------------------------------------
// Property slot table (shared with the interpreter's lowering)
// ---------------------------------------------------------------------------

/// Property slot metadata: drives `Env` allocation in the interpreter and
/// device-buffer declarations in the text backends.
#[derive(Clone, Debug)]
pub struct PropMeta {
    pub name: String,
    pub ty: ScalarTy,
    pub edge: bool,
    pub param: bool,
}

impl PropMeta {
    /// Host symbol for this buffer's element count in generated code
    /// (`V` node-sized, `E` edge-sized) — one definition for every renderer.
    pub fn len_sym(&self) -> &'static str {
        if self.edge {
            "E"
        } else {
            "V"
        }
    }
}

/// The canonical property-slot assignment: name → dense `u32`, parameters
/// first, then body declarations (sema's `prop_order`). Both the interpreter
/// ([`crate::backends::interp::compile`]) and [`DevicePlan::build`] construct
/// their numbering through this table, so all backends agree by construction.
#[derive(Clone, Debug, Default)]
pub struct PropTable {
    interner: Interner,
    metas: Vec<PropMeta>,
}

impl PropTable {
    pub fn build(tf: &TypedFunction) -> PropTable {
        let mut table = PropTable::default();
        let param_names: std::collections::HashSet<&str> =
            tf.func.params.iter().map(|p| p.name.as_str()).collect();
        for name in &tf.prop_order {
            let (inner, edge) = match (tf.node_props.get(name), tf.edge_props.get(name)) {
                (Some(t), _) => (t, false),
                (None, Some(t)) => (t, true),
                (None, None) => continue,
            };
            let slot = table.interner.intern(name);
            debug_assert_eq!(slot as usize, table.metas.len());
            table.metas.push(PropMeta {
                name: name.clone(),
                ty: ScalarTy::of(inner),
                edge,
                param: param_names.contains(name.as_str()),
            });
        }
        table
    }

    /// Slot of a registered property.
    pub fn slot(&self, name: &str) -> Option<u32> {
        self.interner.get(name)
    }

    pub fn meta(&self, slot: u32) -> &PropMeta {
        &self.metas[slot as usize]
    }

    pub fn metas(&self) -> &[PropMeta] {
        &self.metas
    }

    pub fn len(&self) -> usize {
        self.metas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metas.is_empty()
    }

    pub fn into_metas(self) -> Vec<PropMeta> {
        self.metas
    }
}

// ---------------------------------------------------------------------------
// Buffers and kernel parameters
// ---------------------------------------------------------------------------

/// Graph CSR arrays (§4.1: copied to the device once, never back).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphArray {
    Offsets,
    EdgeList,
    RevOffsets,
    SrcList,
}

impl GraphArray {
    /// Device pointer name used by the CUDA and OpenCL renderers.
    pub fn device_name(self) -> &'static str {
        match self {
            GraphArray::Offsets => "gpu_OA",
            GraphArray::EdgeList => "gpu_edgeList",
            GraphArray::RevOffsets => "gpu_rev_OA",
            GraphArray::SrcList => "gpu_srcList",
        }
    }
}

/// One DSL-function parameter, backend-neutral. All C-family backends render
/// the same host signature from this list.
#[derive(Clone, Debug)]
pub enum HostParam {
    Graph { name: String },
    Prop { slot: u32 },
    Set { name: String },
    Scalar { name: String, ty: ScalarTy },
}

/// One kernel parameter, in the plan's canonical order: `V`, graph arrays,
/// property buffers in slot order, reduction cells, scalar params, and the
/// fixedPoint OR-flag last.
#[derive(Clone, Debug)]
pub enum KernelParam {
    NumNodes,
    Graph(GraphArray),
    Prop(u32),
    ReductionCell { name: String, ty: ScalarTy },
    Scalar { name: String, ty: ScalarTy },
    OrFlag,
}

/// Launch schedule entry for one device kernel: everything a renderer needs
/// that is not plain statement syntax.
#[derive(Clone, Debug)]
pub struct KernelPlan {
    pub id: usize,
    pub kind: KernelKind,
    /// stable kernel symbol, shared by all backends that name kernels
    pub name: String,
    pub in_host_loop: bool,
    /// property slots the kernel touches, in interner (slot) order
    pub props: Vec<u32>,
    pub uses_in_edges: bool,
    /// deduplicated scalar reductions `(name, op, machine type)`
    pub reductions: Vec<(String, ReduceOp, ScalarTy)>,
    /// by-value scalar parameters `(name, machine type)`
    pub scalar_params: Vec<(String, ScalarTy)>,
    /// §4.1: property slots owed an H2D copy before this launch
    pub copy_in: Vec<u32>,
    /// §4.1: property slots copied back after the launch…
    pub copy_out: Vec<u32>,
    /// …unless deferred to the enclosing convergence loop's exit
    pub defer_to_loop_exit: bool,
}

impl KernelPlan {
    /// Canonical parameter list. `with_flag` appends the fixedPoint OR-flag
    /// cell when the launch site sits inside a convergence loop.
    pub fn params(&self, with_flag: bool) -> Vec<KernelParam> {
        let mut out = vec![
            KernelParam::NumNodes,
            KernelParam::Graph(GraphArray::Offsets),
            KernelParam::Graph(GraphArray::EdgeList),
        ];
        if self.uses_in_edges {
            out.push(KernelParam::Graph(GraphArray::RevOffsets));
            out.push(KernelParam::Graph(GraphArray::SrcList));
        }
        for &p in &self.props {
            out.push(KernelParam::Prop(p));
        }
        for (name, _, ty) in &self.reductions {
            out.push(KernelParam::ReductionCell { name: name.clone(), ty: *ty });
        }
        for (name, ty) in &self.scalar_params {
            out.push(KernelParam::Scalar { name: name.clone(), ty: *ty });
        }
        if with_flag {
            out.push(KernelParam::OrFlag);
        }
        out
    }

    /// Parameter list for a BFS-loop kernel. The BFS skeleton binds the
    /// level buffer, depth cell, and finished flag itself; `level` is the
    /// enclosing [`BfsPlan`]'s declared level slot, excluded here because
    /// the skeleton passes that buffer explicitly.
    pub fn bfs_params(&self, level: Option<u32>) -> Vec<KernelParam> {
        self.params(false)
            .into_iter()
            .filter(|p| !matches!(p, KernelParam::Prop(s) if Some(*s) == level))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Host-loop skeletons
// ---------------------------------------------------------------------------

/// `fixedPoint` skeleton (Fig 12): convergence is OR-reduced into a single
/// device flag word that ping-pongs host↔device each iteration (§4.1).
#[derive(Clone, Debug)]
pub struct FixedPointPlan {
    /// slot of the bool property whose OR drives convergence, when the
    /// condition has the supported `!prop` shape
    pub flag: Option<u32>,
    /// its name (empty when unsupported) — renderers quote it in comments
    pub flag_name: String,
}

/// `iterateInBFS` skeleton (Fig 9): a level-synchronous host do-while over
/// the forward kernel, plus an optional reverse sweep walking levels back.
#[derive(Clone, Debug)]
pub struct BfsPlan {
    /// kernel id of the forward sweep
    pub fwd: usize,
    /// kernel id of the `iterateInReverse` sweep, if present
    pub rev: Option<usize>,
    /// slot of a *declared* `level` property (BFS over an implicit level
    /// buffer, as in BC, leaves this `None`). The StarPlat construct never
    /// names its level storage, so binding is by the conventional property
    /// name `level` — the same convention the kernel-body emitter uses for
    /// the §3.4 BFS-DAG filter.
    pub level: Option<u32>,
}

// ---------------------------------------------------------------------------
// The device plan
// ---------------------------------------------------------------------------

/// The complete backend-neutral lowering of one DSL function. See the module
/// docs for what each piece replaces in the old per-backend emitters.
#[derive(Clone, Debug)]
pub struct DevicePlan {
    /// DSL function name (kernel names derive from it)
    pub func: String,
    /// canonical property slot table (shared with the interpreter)
    pub props: PropTable,
    pub host_params: Vec<HostParam>,
    /// CSR arrays needed on the device (reverse CSR only when some kernel
    /// pulls over in-edges)
    pub graph_arrays: Vec<GraphArray>,
    /// property slots device-resident for the whole function, slot order
    pub device_resident: Vec<u32>,
    /// property slots returning to the host at exit (outputs-only D2H)
    pub outputs: Vec<u32>,
    pub kernels: Vec<KernelPlan>,
    /// fixedPoint skeletons in program order
    pub fixed_points: Vec<FixedPointPlan>,
    /// iterateInBFS skeletons in program order
    pub bfs_loops: Vec<BfsPlan>,
}

impl DevicePlan {
    pub fn build(ir: &IrProgram) -> DevicePlan {
        let tf = &ir.tf;
        let props = PropTable::build(tf);

        let host_params = tf
            .func
            .params
            .iter()
            .map(|p| match &p.ty {
                Type::Graph => HostParam::Graph { name: p.name.clone() },
                Type::PropNode(_) | Type::PropEdge(_) => HostParam::Prop {
                    slot: props.slot(&p.name).expect("property parameter registered"),
                },
                Type::SetN(_) => HostParam::Set { name: p.name.clone() },
                t => HostParam::Scalar { name: p.name.clone(), ty: ScalarTy::of(t) },
            })
            .collect();

        let mut graph_arrays = vec![GraphArray::Offsets, GraphArray::EdgeList];
        if ir.kernels.iter().any(|k| k.uses.uses_in_edges) {
            graph_arrays.push(GraphArray::RevOffsets);
            graph_arrays.push(GraphArray::SrcList);
        }

        let mut device_resident: Vec<u32> = ir
            .transfer
            .device_resident_props
            .iter()
            .filter_map(|n| props.slot(n))
            .collect();
        device_resident.sort_unstable();
        device_resident.dedup();

        let mut outputs: Vec<u32> =
            ir.transfer.outputs.iter().filter_map(|n| props.slot(n)).collect();
        outputs.sort_unstable();
        outputs.dedup();

        let kernels = ir.kernels.iter().map(|k| kernel_plan(ir, &props, k)).collect();

        let mut fixed_points = Vec::new();
        let mut bfs_loops = Vec::new();
        let mut next_kernel = 0usize;
        collect_host_loops(
            &tf.func.body,
            &props,
            &mut next_kernel,
            &mut fixed_points,
            &mut bfs_loops,
        );
        // hard assert (one usize compare per build): the host-loop walk must
        // mirror `ir::collect_kernels` exactly, or every downstream kernel id
        // would be silently shifted
        assert_eq!(next_kernel, ir.kernels.len(), "host-loop walk drifted from schedule");

        DevicePlan {
            func: tf.func.name.clone(),
            props,
            host_params,
            graph_arrays,
            device_resident,
            outputs,
            kernels,
            fixed_points,
            bfs_loops,
        }
    }

    pub fn meta(&self, slot: u32) -> &PropMeta {
        self.props.meta(slot)
    }

    pub fn prop_name(&self, slot: u32) -> &str {
        &self.props.meta(slot).name
    }

    /// Machine type of a property by name (I32 when unknown, matching the
    /// old emitters' fallback).
    pub fn prop_ty_of(&self, name: &str) -> ScalarTy {
        self.props.slot(name).map(|s| self.props.meta(s).ty).unwrap_or(ScalarTy::I32)
    }

    /// Rendered type of a property by name, through a backend's map.
    pub fn c_ty_of(&self, name: &str, map: &TypeMap) -> &'static str {
        map.name(self.prop_ty_of(name))
    }

    /// Rendered type of a property slot, through a backend's map.
    pub fn c_ty(&self, slot: u32, map: &TypeMap) -> &'static str {
        map.name(self.props.meta(slot).ty)
    }

    /// Output property names in slot order (JAX buffer bindings).
    pub fn output_names(&self) -> Vec<&str> {
        self.outputs.iter().map(|&s| self.props.meta(s).name.as_str()).collect()
    }

    /// Is `name` a declared *node* property? Renderers use this to classify
    /// whole-property assignment targets (`modified = modified_nxt`).
    pub fn is_node_prop(&self, name: &str) -> bool {
        matches!(self.props.slot(name), Some(s) if !self.props.meta(s).edge)
    }

    /// Launch-site argument name for a kernel parameter — identical across
    /// the pointer-passing backends (CUDA, OpenCL), so it lives here.
    pub fn launch_arg(&self, p: &KernelParam) -> String {
        match p {
            KernelParam::NumNodes => "V".to_string(),
            KernelParam::Graph(a) => a.device_name().to_string(),
            KernelParam::Prop(s) => format!("gpu_{}", self.prop_name(*s)),
            KernelParam::ReductionCell { name, .. } => format!("d_{name}"),
            KernelParam::Scalar { name, .. } => name.clone(),
            KernelParam::OrFlag => "gpu_finished".to_string(),
        }
    }

    /// The host function signature shared by the C-family backends.
    pub fn host_signature(&self, map: &TypeMap) -> Vec<String> {
        self.host_params
            .iter()
            .map(|p| match p {
                HostParam::Graph { name } => format!("graph& {name}"),
                HostParam::Prop { slot } => {
                    let m = self.props.meta(*slot);
                    format!("{}* {}", map.name(m.ty), m.name)
                }
                HostParam::Set { name } => format!("std::set<int>& {name}"),
                HostParam::Scalar { name, ty } => format!("{} {name}", map.name(*ty)),
            })
            .collect()
    }

    /// Stable, backend-neutral description of the plan. Every text renderer
    /// embeds this as a comment block; `tests/plan_numbering.rs` asserts it
    /// is byte-identical across the four backends.
    pub fn manifest(&self) -> Vec<String> {
        let mut out = Vec::new();
        out.push(format!(
            "==== device plan: {} ({} buffers, {} kernels) ====",
            self.func,
            self.props.len(),
            self.kernels.len()
        ));
        for (i, m) in self.props.metas().iter().enumerate() {
            let mut tags = vec![if m.edge { "edge" } else { "node" }];
            if m.param {
                tags.push("param");
            }
            if self.outputs.contains(&(i as u32)) {
                tags.push("output");
            }
            out.push(format!(
                "buffer[{i}] {} : {} ({})",
                m.name,
                TypeMap::C.name(m.ty),
                tags.join(", ")
            ));
        }
        for k in &self.kernels {
            out.push(format!(
                "kernel[{}] {} {}{}",
                k.id,
                kind_token(&k.kind),
                k.name,
                if k.in_host_loop { " [host-loop]" } else { "" }
            ));
        }
        for (i, f) in self.fixed_points.iter().enumerate() {
            out.push(format!("fixedPoint[{i}] flag=`{}`", f.flag_name));
        }
        for (i, b) in self.bfs_loops.iter().enumerate() {
            match b.rev {
                Some(r) => out.push(format!("bfs[{i}] fwd=kernel[{}] rev=kernel[{}]", b.fwd, r)),
                None => out.push(format!("bfs[{i}] fwd=kernel[{}]", b.fwd)),
            }
        }
        out.push("==== end device plan ====".to_string());
        out
    }
}

fn kind_token(k: &KernelKind) -> &'static str {
    match k {
        KernelKind::InitProps => "init",
        KernelKind::VertexParallel => "vertex",
        KernelKind::BfsForward => "bfs-fwd",
        KernelKind::BfsReverse => "bfs-rev",
    }
}

fn kernel_name(func: &str, k: &Kernel) -> String {
    match k.kind {
        KernelKind::InitProps => format!("{func}_init_{}", k.id),
        KernelKind::VertexParallel => format!("{func}_kernel_{}", k.id),
        KernelKind::BfsForward => format!("{func}_bfs_kernel_{}", k.id),
        KernelKind::BfsReverse => format!("{func}_bfs_rev_kernel_{}", k.id),
    }
}

fn kernel_plan(ir: &IrProgram, props: &PropTable, k: &Kernel) -> KernelPlan {
    let tf = &ir.tf;
    let transfers = &ir.transfer.per_kernel[k.id];

    let mut pslots: Vec<u32> = k
        .uses
        .props_read
        .union(&k.uses.props_written)
        .filter_map(|n| props.slot(n))
        .collect();
    pslots.sort_unstable();
    pslots.dedup();

    let mut reductions: Vec<(String, ReduceOp, ScalarTy)> = Vec::new();
    for (r, op) in &k.uses.reductions {
        if reductions.iter().any(|(n, _, _)| n == r) {
            continue;
        }
        let ty = tf.vars.get(r).map(ScalarTy::of).unwrap_or(ScalarTy::I64);
        reductions.push((r.clone(), *op, ty));
    }

    // Scalars passed by value: declared non-prop, non-graph, non-set
    // variables the kernel reads — minus reduction targets, which already
    // travel as device cells.
    let scalar_params: Vec<(String, ScalarTy)> = transfers
        .scalar_params
        .iter()
        .filter(|s| !reductions.iter().any(|(n, _, _)| n == *s))
        .filter_map(|s| match tf.vars.get(s) {
            Some(ty) if !ty.is_prop() && !matches!(ty, Type::Graph | Type::SetN(_)) => {
                Some((s.clone(), ScalarTy::of(ty)))
            }
            _ => None,
        })
        .collect();

    KernelPlan {
        id: k.id,
        kind: k.kind.clone(),
        name: kernel_name(&tf.func.name, k),
        in_host_loop: k.in_host_loop,
        props: pslots,
        uses_in_edges: k.uses.uses_in_edges,
        reductions,
        scalar_params,
        copy_in: transfers.copy_in.iter().filter_map(|n| props.slot(n)).collect(),
        copy_out: transfers.copy_out.iter().filter_map(|n| props.slot(n)).collect(),
        defer_to_loop_exit: transfers.defer_to_loop_exit,
    }
}

/// Walk the function body in the exact order of `ir::collect_kernels`,
/// recording fixedPoint / BFS skeletons against the kernel schedule.
fn collect_host_loops(
    block: &[Stmt],
    props: &PropTable,
    next_kernel: &mut usize,
    fixed_points: &mut Vec<FixedPointPlan>,
    bfs_loops: &mut Vec<BfsPlan>,
) {
    for s in block {
        match s {
            Stmt::AttachNodeProperty { .. } => *next_kernel += 1,
            Stmt::For { parallel: true, .. } => *next_kernel += 1,
            Stmt::For { parallel: false, body, .. } => {
                collect_host_loops(body, props, next_kernel, fixed_points, bfs_loops);
            }
            Stmt::IterateBFS { reverse, .. } => {
                let fwd = *next_kernel;
                *next_kernel += 1;
                let rev = reverse.as_ref().map(|_| {
                    let r = *next_kernel;
                    *next_kernel += 1;
                    r
                });
                bfs_loops.push(BfsPlan { fwd, rev, level: props.slot("level") });
            }
            Stmt::FixedPoint { cond, body, .. } => {
                let flag_name = crate::ir::or_flag_prop(cond).unwrap_or_default();
                fixed_points.push(FixedPointPlan { flag: props.slot(&flag_name), flag_name });
                collect_host_loops(body, props, next_kernel, fixed_points, bfs_loops);
            }
            Stmt::DoWhile { body, .. } | Stmt::While { body, .. } => {
                collect_host_loops(body, props, next_kernel, fixed_points, bfs_loops);
            }
            Stmt::If { then, els, .. } => {
                collect_host_loops(then, props, next_kernel, fixed_points, bfs_loops);
                if let Some(e) = els {
                    collect_host_loops(e, props, next_kernel, fixed_points, bfs_loops);
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// Schedule cursor
// ---------------------------------------------------------------------------

/// Walks the plan's schedules in program order, mirroring a renderer's AST
/// walk: kernel-site statements consume entries instead of re-deriving ids.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanCursor {
    kernel: usize,
    fixed_point: usize,
    bfs: usize,
}

impl PlanCursor {
    /// Next kernel at an `attachNodeProperty` or parallel-`forall` site.
    pub fn next_kernel<'p>(&mut self, plan: &'p DevicePlan) -> &'p KernelPlan {
        let k = &plan.kernels[self.kernel];
        self.kernel += 1;
        k
    }

    /// Next `fixedPoint` skeleton.
    pub fn next_fixed_point<'p>(&mut self, plan: &'p DevicePlan) -> &'p FixedPointPlan {
        let f = &plan.fixed_points[self.fixed_point];
        self.fixed_point += 1;
        f
    }

    /// Next `iterateInBFS` skeleton: the loop plan, its forward kernel and,
    /// when the construct has an `iterateInReverse` arm, the reverse kernel.
    /// Advances the kernel cursor past both.
    pub fn next_bfs<'p>(
        &mut self,
        plan: &'p DevicePlan,
    ) -> (&'p BfsPlan, &'p KernelPlan, Option<&'p KernelPlan>) {
        let b = &plan.bfs_loops[self.bfs];
        self.bfs += 1;
        let fwd = &plan.kernels[b.fwd];
        let rev = b.rev.map(|i| &plan.kernels[i]);
        self.kernel = b.fwd + 1 + usize::from(b.rev.is_some());
        (b, fwd, rev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse;
    use crate::ir::lower;
    use crate::sema::check_function;

    fn plan_of(p: &str) -> DevicePlan {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(p);
        let src = std::fs::read_to_string(&path).unwrap();
        let fns = parse(&src).unwrap();
        let tf = check_function(&fns[0]).unwrap();
        DevicePlan::build(&lower(&tf))
    }

    #[test]
    fn sssp_buffers_in_declaration_order() {
        let plan = plan_of("sssp.sp");
        let names: Vec<&str> = plan.props.metas().iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, vec!["dist", "weight", "modified", "modified_nxt"]);
        assert!(plan.props.meta(1).edge && plan.props.meta(1).param);
        assert_eq!(plan.outputs, vec![0]); // dist
        assert_eq!(plan.graph_arrays, vec![GraphArray::Offsets, GraphArray::EdgeList]);
    }

    #[test]
    fn sssp_relax_kernel_params_in_slot_order() {
        let plan = plan_of("sssp.sp");
        let relax = &plan.kernels[1];
        assert_eq!(relax.kind, KernelKind::VertexParallel);
        assert!(relax.in_host_loop && relax.defer_to_loop_exit);
        // props in interner order: dist(0), weight(1), modified(2), modified_nxt(3)
        assert_eq!(relax.props, vec![0, 1, 2, 3]);
        let params = relax.params(true);
        assert!(matches!(params[0], KernelParam::NumNodes));
        assert!(matches!(params.last(), Some(KernelParam::OrFlag)));
        // weight is owed an H2D copy before the first launch (§4.1)
        assert_eq!(relax.copy_in, vec![1]);
    }

    #[test]
    fn fixed_point_skeletons_carry_the_flag() {
        for p in ["sssp.sp", "cc.sp"] {
            let plan = plan_of(p);
            assert_eq!(plan.fixed_points.len(), 1, "{p}");
            let fp = &plan.fixed_points[0];
            assert_eq!(fp.flag_name, "modified", "{p}");
            assert_eq!(fp.flag, plan.props.slot("modified"), "{p}");
        }
    }

    #[test]
    fn bc_bfs_skeleton_binds_both_sweeps() {
        let plan = plan_of("bc.sp");
        assert_eq!(plan.bfs_loops.len(), 1);
        let b = &plan.bfs_loops[0];
        assert_eq!(plan.kernels[b.fwd].kind, KernelKind::BfsForward);
        assert_eq!(plan.kernels[b.rev.unwrap()].kind, KernelKind::BfsReverse);
        assert!(b.level.is_none(), "bc's level buffer is implicit");
        // bfs.sp declares `level`, so its skeleton binds the slot
        let bfs = plan_of("bfs.sp");
        assert_eq!(bfs.bfs_loops[0].level, bfs.props.slot("level"));
    }

    #[test]
    fn cursor_walks_the_schedule_in_order() {
        let plan = plan_of("bc.sp");
        let mut cur = PlanCursor::default();
        let k0 = cur.next_kernel(&plan);
        assert_eq!(k0.id, 0);
        // bc: attach(BC), then per-source attach(delta,sigma), then BFS fwd+rev
        let k1 = cur.next_kernel(&plan);
        assert_eq!(k1.kind, KernelKind::InitProps);
        let (b, fwd, rev) = cur.next_bfs(&plan);
        assert_eq!(fwd.kind, KernelKind::BfsForward);
        assert!(rev.is_some());
        assert_eq!(b.fwd, fwd.id);
    }

    #[test]
    fn opencl_type_map_demotes_bool() {
        assert_eq!(TypeMap::OPENCL.name(ScalarTy::Bool), "int");
        assert_eq!(TypeMap::C.name(ScalarTy::Bool), "bool");
        assert_eq!(TypeMap::NUMPY.name(ScalarTy::F32), "float32");
        let plan = plan_of("sssp.sp");
        assert_eq!(plan.c_ty_of("modified", &TypeMap::OPENCL), "int");
        assert_eq!(plan.c_ty_of("modified", &TypeMap::C), "bool");
    }

    #[test]
    fn manifest_is_deterministic_and_complete() {
        let a = plan_of("sssp.sp").manifest();
        let b = plan_of("sssp.sp").manifest();
        assert_eq!(a, b);
        assert!(a[0].contains("device plan: Compute_SSSP"));
        assert!(a.iter().any(|l| l.contains("buffer[0] dist")));
        assert!(a.iter().any(|l| l.contains("fixedPoint[0] flag=`modified`")));
        assert_eq!(a.last().unwrap(), "==== end device plan ====");
    }

    #[test]
    fn kernel_ids_match_ir_schedule_positions() {
        for p in ["bc.sp", "pr.sp", "sssp.sp", "tc.sp", "cc.sp", "bfs.sp"] {
            let plan = plan_of(p);
            for (i, k) in plan.kernels.iter().enumerate() {
                assert_eq!(k.id, i, "{p}");
                // slot-order invariant on every parameter list
                let mut prev = None;
                for s in &k.props {
                    if let Some(q) = prev {
                        assert!(q < *s, "{p}: kernel {i} props unsorted");
                    }
                    prev = Some(*s);
                }
            }
        }
    }
}
