//! Backend-neutral device plan: the single lowering layer between the IR and
//! every accelerator renderer.
//!
//! # Pipeline
//!
//! ```text
//! AST (dsl::ast) ──sema──▶ TypedFunction ──ir::lower──▶ IrProgram
//!                                                          │
//!                                          DevicePlan::build (this module)
//!                                                          │
//!                    ┌───────────────┬────────────┬────────┴───┬───────────┐
//!                    ▼               ▼            ▼            ▼           ▼
//!              codegen::cuda  codegen::opencl codegen::sycl codegen::openacc
//!                    └───────────────┴────────────┴────────────┘      codegen::jax
//!                                 (thin renderers: syntax only)
//! ```
//!
//! The paper's core claim (§3) is one algorithmic specification feeding CUDA,
//! OpenCL, SYCL, and OpenACC generators. Before this layer existed, each of
//! the four text emitters re-derived function parameters, device-buffer sets,
//! property C types, and kernel numbering independently from the AST — four
//! copies of the same analysis. The [`DevicePlan`] resolves all of that once:
//!
//! - **buffers**: every node/edge property gets a stable slot from the same
//!   [`PropTable`] the interpreter's lowering uses ([`crate::backends::interp::compile`]
//!   calls [`PropTable::build`] too), so interpreter and codegen agree on
//!   numbering *by construction*;
//! - **types**: scalar machine types are mapped per backend through a
//!   [`TypeMap`] hook (e.g. OpenCL has no device-side `bool` arrays, so its
//!   map sends `Bool` to `int`) — resolved here, not in emitters;
//! - **kernel schedule**: one [`KernelPlan`] per IR kernel, carrying its name,
//!   its parameter list in interner (slot) order, and the bound §4 transfer
//!   steps (graph CSR H2D once; property copy-ins owed before first launch;
//!   outputs-only D2H, deferred past convergence loops);
//! - **host-loop skeletons**: [`FixedPointPlan`] (Fig 12's device-flag
//!   ping-pong) and [`BfsPlan`] (Fig 9's level-synchronous do-while) in
//!   program order;
//! - **host-statement schedule**: the complete host half of the function as
//!   a [`HostOp`] tree ([`DevicePlan::host_ops`]) — declarations, scalar
//!   init, transfers, launches, loop/branch structure, epilogue frees —
//!   rendered by the one `codegen::render_host_schedule` driver. Renderers
//!   never walk the AST for host syntax; a new backend is a spelling table.
//!
//! A renderer walks the AST only for *kernel-body syntax* (expressions, loop
//! shapes inside device code); everything else comes from the plan. Every
//! renderer also embeds [`DevicePlan::manifest`] and
//! [`DevicePlan::host_manifest`] as comment blocks, which are byte-identical
//! across backends — `tests/plan_numbering.rs` and
//! `tests/host_schedule_conformance.rs` snapshot them to pin the
//! cross-backend guarantee.

use crate::dsl::ast::{BinOp, Expr, IterSource, LValue, MinMax, ReduceOp, Stmt, Type, UnOp};
use crate::dsl::diag::DslError;
use crate::ir::kernel::{
    lower_kernel_body, pull_variant, resolve_filter, simplify_bool_cmp, BfsDir, KCell, KTarget,
    KernelBody, KernelLower, KernelOp,
};
use crate::ir::slots::Interner;
use crate::ir::{IrProgram, Kernel, KernelKind, ScalarTy};
use crate::sema::TypedFunction;

// ---------------------------------------------------------------------------
// Per-backend type mapping
// ---------------------------------------------------------------------------

/// Scalar-type spelling for one backend. The hooks live here so a backend's
/// quirks (OpenCL's missing device `bool`, numpy dtype names) are resolved in
/// one place instead of inside each emitter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TypeMap {
    pub int: &'static str,
    pub long: &'static str,
    pub float: &'static str,
    pub double: &'static str,
    pub boolean: &'static str,
}

impl TypeMap {
    /// C / C++ family (CUDA, SYCL, OpenACC, and every host half).
    pub const C: TypeMap = TypeMap {
        int: "int",
        long: "long long",
        float: "float",
        double: "double",
        boolean: "bool",
    };
    /// OpenCL C device code: no `bool` arrays (§3), 64-bit int is `long`.
    pub const OPENCL: TypeMap = TypeMap {
        int: "int",
        long: "long",
        float: "float",
        double: "double",
        boolean: "int",
    };
    /// numpy dtype names, for the JAX backend's buffer bindings.
    pub const NUMPY: TypeMap = TypeMap {
        int: "int32",
        long: "int64",
        float: "float32",
        double: "float64",
        boolean: "bool_",
    };
    /// Metal Shading Language device code: no `long long` (64-bit int is
    /// `long`) and no `double` (demotes to `float`).
    pub const METAL: TypeMap = TypeMap {
        int: "int",
        long: "long",
        float: "float",
        double: "float",
        boolean: "bool",
    };
    /// WGSL device code: 32-bit scalars only, and `bool` is not
    /// host-shareable — boolean buffers are `i32` words.
    pub const WGSL: TypeMap = TypeMap {
        int: "i32",
        long: "i32",
        float: "f32",
        double: "f32",
        boolean: "i32",
    };

    pub fn name(&self, t: ScalarTy) -> &'static str {
        match t {
            ScalarTy::I32 => self.int,
            ScalarTy::I64 => self.long,
            ScalarTy::F32 => self.float,
            ScalarTy::F64 => self.double,
            ScalarTy::Bool => self.boolean,
        }
    }
}

// ---------------------------------------------------------------------------
// Property slot table (shared with the interpreter's lowering)
// ---------------------------------------------------------------------------

/// Property slot metadata: drives `Env` allocation in the interpreter and
/// device-buffer declarations in the text backends.
#[derive(Clone, Debug)]
pub struct PropMeta {
    pub name: String,
    pub ty: ScalarTy,
    pub edge: bool,
    pub param: bool,
    /// plan-synthesized buffer (e.g. the BFS level save/restore scratch),
    /// not a DSL-declared property — never present in the interpreter's
    /// table, always slotted after every declared property
    pub synthetic: bool,
}

impl PropMeta {
    /// Host symbol for this buffer's element count in generated code
    /// (`V` node-sized, `E` edge-sized) — one definition for every renderer.
    pub fn len_sym(&self) -> &'static str {
        if self.edge {
            "E"
        } else {
            "V"
        }
    }
}

/// The canonical property-slot assignment: name → dense `u32`, parameters
/// first, then body declarations (sema's `prop_order`). Both the interpreter
/// ([`crate::backends::interp::compile`]) and [`DevicePlan::build`] construct
/// their numbering through this table, so all backends agree by construction.
#[derive(Clone, Debug, Default)]
pub struct PropTable {
    interner: Interner,
    metas: Vec<PropMeta>,
}

impl PropTable {
    pub fn build(tf: &TypedFunction) -> PropTable {
        let mut table = PropTable::default();
        let param_names: std::collections::HashSet<&str> =
            tf.func.params.iter().map(|p| p.name.as_str()).collect();
        for name in &tf.prop_order {
            let (inner, edge) = match (tf.node_props.get(name), tf.edge_props.get(name)) {
                (Some(t), _) => (t, false),
                (None, Some(t)) => (t, true),
                (None, None) => continue,
            };
            let slot = table.interner.intern(name);
            debug_assert_eq!(slot as usize, table.metas.len());
            table.metas.push(PropMeta {
                name: name.clone(),
                ty: ScalarTy::of(inner),
                edge,
                param: param_names.contains(name.as_str()),
                synthetic: false,
            });
        }
        table
    }

    /// Append a plan-synthesized buffer. Always slotted *after* every
    /// declared property, so the numbering the interpreter derives from the
    /// same `TypedFunction` stays a prefix of the plan's.
    pub fn push_synthetic(&mut self, name: &str, ty: ScalarTy, edge: bool) -> u32 {
        let slot = self.interner.intern(name);
        debug_assert_eq!(slot as usize, self.metas.len());
        self.metas.push(PropMeta {
            name: name.to_string(),
            ty,
            edge,
            param: false,
            synthetic: true,
        });
        slot
    }

    /// Slot of a registered property.
    pub fn slot(&self, name: &str) -> Option<u32> {
        self.interner.get(name)
    }

    pub fn meta(&self, slot: u32) -> &PropMeta {
        &self.metas[slot as usize]
    }

    pub fn metas(&self) -> &[PropMeta] {
        &self.metas
    }

    pub fn len(&self) -> usize {
        self.metas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metas.is_empty()
    }

    pub fn into_metas(self) -> Vec<PropMeta> {
        self.metas
    }
}

// ---------------------------------------------------------------------------
// Buffers and kernel parameters
// ---------------------------------------------------------------------------

/// Graph CSR arrays (§4.1: copied to the device once, never back).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphArray {
    Offsets,
    EdgeList,
    RevOffsets,
    SrcList,
}

impl GraphArray {
    /// Device pointer name used by the CUDA-family and OpenCL renderers.
    pub fn device_name(self) -> &'static str {
        match self {
            GraphArray::Offsets => "gpu_OA",
            GraphArray::EdgeList => "gpu_edgeList",
            GraphArray::RevOffsets => "gpu_rev_OA",
            GraphArray::SrcList => "gpu_srcList",
        }
    }

    /// Host-side CSR member the array is copied from.
    pub fn host_name(self) -> &'static str {
        match self {
            GraphArray::Offsets => "g.indexofNodes",
            GraphArray::EdgeList => "g.edgeList",
            GraphArray::RevOffsets => "g.rev_indexofNodes",
            GraphArray::SrcList => "g.srcList",
        }
    }

    /// Element count expression (in terms of the generated `V` / `E` locals).
    pub fn len_sym(self) -> &'static str {
        match self {
            GraphArray::Offsets | GraphArray::RevOffsets => "(1 + V)",
            GraphArray::EdgeList | GraphArray::SrcList => "E",
        }
    }
}

/// One DSL-function parameter, backend-neutral. All C-family backends render
/// the same host signature from this list.
#[derive(Clone, Debug)]
pub enum HostParam {
    Graph { name: String },
    Prop { slot: u32 },
    Set { name: String },
    Scalar { name: String, ty: ScalarTy },
}

/// One kernel parameter, in the plan's canonical order: `V`, graph arrays,
/// property buffers in slot order, reduction cells, scalar params, and the
/// fixedPoint OR-flag last.
#[derive(Clone, Debug)]
pub enum KernelParam {
    NumNodes,
    Graph(GraphArray),
    Prop(u32),
    ReductionCell { name: String, ty: ScalarTy },
    Scalar { name: String, ty: ScalarTy },
    OrFlag,
}

/// Launch schedule entry for one device kernel: everything a renderer needs
/// that is not plain statement syntax.
#[derive(Clone, Debug)]
pub struct KernelPlan {
    pub id: usize,
    pub kind: KernelKind,
    /// stable kernel symbol, shared by all backends that name kernels
    pub name: String,
    pub in_host_loop: bool,
    /// property slots the kernel touches, in interner (slot) order
    pub props: Vec<u32>,
    pub uses_in_edges: bool,
    /// deduplicated scalar reductions `(name, op, machine type)`
    pub reductions: Vec<(String, ReduceOp, ScalarTy)>,
    /// by-value scalar parameters `(name, machine type)`
    pub scalar_params: Vec<(String, ScalarTy)>,
    /// §4.1: property slots owed an H2D copy before this launch
    pub copy_in: Vec<u32>,
    /// §4.1: property slots copied back after the launch…
    pub copy_out: Vec<u32>,
    /// …unless deferred to the enclosing convergence loop's exit
    pub defer_to_loop_exit: bool,
    /// the lowered device body ([`crate::ir::kernel`]), filled in by the
    /// host walk (which knows the fixedPoint / BFS context). `None` only
    /// for [`KernelKind::InitProps`] kernels, whose inits ride on
    /// [`HostOp::InitProps`].
    pub body: Option<KernelBody>,
    /// property slots this body updates atomically, sorted — dialects with
    /// typed atomics (Metal, WGSL) declare these buffers differently
    pub atomic_props: Vec<u32>,
    /// the pull-direction twin of `body`, when the schedule pass derived one
    /// ([`crate::ir::kernel::pull_variant`]): renderers emit a second
    /// `{name}_pull` kernel and a host-side `STARPLAT_DIRECTION` switch
    pub pull_body: Option<KernelBody>,
    /// plan-synthesized kernel (the BFS level restore launch), absent from
    /// the IR kernel schedule — always appended after every IR kernel so
    /// `ir.kernels` ids stay a prefix of the plan's
    pub synthetic: bool,
}

impl KernelPlan {
    /// Canonical parameter list. `with_flag` appends the fixedPoint OR-flag
    /// cell when the launch site sits inside a convergence loop.
    pub fn params(&self, with_flag: bool) -> Vec<KernelParam> {
        let mut out = vec![
            KernelParam::NumNodes,
            KernelParam::Graph(GraphArray::Offsets),
            KernelParam::Graph(GraphArray::EdgeList),
        ];
        if self.uses_in_edges {
            out.push(KernelParam::Graph(GraphArray::RevOffsets));
            out.push(KernelParam::Graph(GraphArray::SrcList));
        }
        for &p in &self.props {
            out.push(KernelParam::Prop(p));
        }
        for (name, _, ty) in &self.reductions {
            out.push(KernelParam::ReductionCell { name: name.clone(), ty: *ty });
        }
        for (name, ty) in &self.scalar_params {
            out.push(KernelParam::Scalar { name: name.clone(), ty: *ty });
        }
        if with_flag {
            out.push(KernelParam::OrFlag);
        }
        out
    }

    /// Parameter list for a BFS-loop kernel. The BFS skeleton binds the
    /// level buffer, depth cell, and finished flag itself; `level` is the
    /// enclosing [`BfsPlan`]'s declared level slot, excluded here because
    /// the skeleton passes that buffer explicitly.
    pub fn bfs_params(&self, level: Option<u32>) -> Vec<KernelParam> {
        self.params(false)
            .into_iter()
            .filter(|p| !matches!(p, KernelParam::Prop(s) if Some(*s) == level))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Schedule plan
// ---------------------------------------------------------------------------

/// Why a kernel did not get a pull variant. Carried in the manifest so the
/// decision (not just its absence) is pinned across backends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOnly {
    /// inits ride on the host schedule; nothing to re-orient
    Init,
    /// one-shot forall — a runtime direction switch buys nothing
    NotIterated,
    /// weighted relaxation: device buffers carry no `rev_edge_id` map, so
    /// the weight of a reverse slot cannot be read (the interpreter pulls
    /// these; generated kernels cannot)
    Weighted,
    /// body shape is not a mechanically re-orientable relaxation
    Shape,
}

impl PushOnly {
    fn token(self) -> &'static str {
        match self {
            PushOnly::Init => "init",
            PushOnly::NotIterated => "not-iterated",
            PushOnly::Weighted => "weighted (no rev_edge_id)",
            PushOnly::Shape => "shape",
        }
    }
}

/// One kernel's schedule decision: which traversal directions it can run in
/// and whether its relaxation is delta-stepping eligible (interpreter only —
/// text backends always emit the sweep).
#[derive(Clone, Debug)]
pub struct ScheduleChoice {
    pub kernel: usize,
    /// `None` means both directions: the renderer emits push and pull
    /// kernels plus a host-side runtime switch on `STARPLAT_DIRECTION`
    pub push_only: Option<PushOnly>,
    /// weighted relaxation in a host loop — the interpreter may route it
    /// through bucketed delta-stepping (`STARPLAT_DELTA`)
    pub delta_eligible: bool,
}

/// The function's traversal-schedule decisions, recorded once at plan time
/// so every backend (and the bench harness) reads the same verdicts.
#[derive(Clone, Debug, Default)]
pub struct SchedulePlan {
    pub choices: Vec<ScheduleChoice>,
    /// an `iterateInBFS` is present: the interpreter runs it
    /// direction-optimized (push/pull per level); text backends keep the
    /// level-synchronous push skeleton
    pub bfs_direction_optimized: bool,
}

/// Classify a lowered body as a relaxation sweep: a single forward
/// unfiltered neighbor loop over the thread vertex whose payload is one
/// `MinMax` (weight-free) or an edge decl plus one `MinMax` (weighted).
fn relax_shape(body: &KernelBody) -> Option<bool /* weighted */> {
    let [KernelOp::NeighborLoop { of, reverse: false, bfs: None, filter: None, body: inner, .. }] =
        &body.ops[..]
    else {
        return None;
    };
    if of != &body.thread_var {
        return None;
    }
    match &inner[..] {
        [KernelOp::MinMax { .. }] => Some(false),
        [KernelOp::Decl { .. }, KernelOp::MinMax { .. }] => Some(true),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Host-loop skeletons
// ---------------------------------------------------------------------------

/// `fixedPoint` skeleton (Fig 12): convergence is OR-reduced into a single
/// device flag word that ping-pongs host↔device each iteration (§4.1).
#[derive(Clone, Debug)]
pub struct FixedPointPlan {
    /// slot of the bool property whose OR drives convergence, when the
    /// condition has the supported `!prop` shape
    pub flag: Option<u32>,
    /// its name (empty when unsupported) — renderers quote it in comments
    pub flag_name: String,
}

/// `iterateInBFS` skeleton (Fig 9): a level-synchronous host do-while over
/// the forward kernel, plus an optional reverse sweep walking levels back.
#[derive(Clone, Debug)]
pub struct BfsPlan {
    /// kernel id of the forward sweep
    pub fwd: usize,
    /// kernel id of the `iterateInReverse` sweep, if present
    pub rev: Option<usize>,
    /// slot of a *declared* `level` property (BFS over an implicit level
    /// buffer, as in BC, leaves this `None`). The StarPlat construct never
    /// names its level storage, so binding is by the conventional property
    /// name `level` — the same convention the kernel-body emitter uses for
    /// the §3.4 BFS-DAG filter.
    pub level: Option<u32>,
}

// ---------------------------------------------------------------------------
// Host-statement schedule
// ---------------------------------------------------------------------------

/// One backend-neutral host-side operation. The complete host half of a
/// generated program — declarations, transfers, launches, loop and branch
/// structure, epilogue frees — is lowered once into a `Vec<HostOp>` tree by
/// [`DevicePlan::build`]; a backend renders it through
/// `codegen::render_host_schedule`, supplying only its spellings
/// (`cudaMemcpy` vs `clEnqueueWriteBuffer` vs SYCL queue ops vs OpenACC
/// pragmas). Renderers never walk the AST for host syntax; device-kernel
/// *bodies* (the [`HostOp::Launch`] / [`HostOp::Bfs`] payloads) are the only
/// AST that reaches them.
#[derive(Clone, Debug)]
pub enum HostOp {
    /// `V` / `E` locals (and per-backend context setup: queue, cl status)
    DeclDims,
    /// §4.1: graph CSR arrays alloc'd + copied host→device, once
    GraphToDevice,
    /// device allocation of one plan buffer
    AllocProp { slot: u32 },
    /// the single fixedPoint OR-flag word (§4.1)
    AllocFlag,
    /// launch-dimension setup (`threadsPerBlock`, ND-range sizes, …)
    LaunchSetup,
    /// host scalar declaration
    DeclScalar { name: String, ty: ScalarTy, init: Option<Expr> },
    /// host scalar assignment
    AssignScalar { name: String, value: Expr },
    /// whole-property device-to-device copy (`modified = modified_nxt`)
    CopyProp { dst: u32, src: u32 },
    /// single-element device store (`src.dist = 0`)
    SetElement { slot: u32, index: String, value: Expr },
    /// host-side scalar reduction statement
    ReduceScalar { name: String, op: ReduceOp, value: Expr },
    /// `attachNodeProperty`: N-wide initialization launch
    InitProps { kernel: usize, inits: Vec<(u32, Expr)> },
    /// parallel `forall`: kernel emission + launch + bound §4 transfers.
    /// The device body is plan-carried ([`KernelPlan::body`]) — no AST here.
    Launch { kernel: usize },
    /// sequential host loop over a node set
    SeqFor { var: String, set: String, body: Vec<HostOp> },
    /// Fig 12 fixedPoint skeleton; body launches see the OR-flag
    FixedPoint { index: usize, var: String, body: Vec<HostOp> },
    /// Fig 9 iterateInBFS skeleton; sweep bodies are plan-carried on the
    /// [`BfsPlan`]'s forward / reverse kernels
    Bfs { index: usize, var: String, from: String },
    DoWhile { body: Vec<HostOp>, cond: Expr },
    While { cond: Expr, body: Vec<HostOp> },
    If { cond: Expr, then: Vec<HostOp>, els: Option<Vec<HostOp>> },
    Return { value: Expr },
    /// host-level construct no backend supports (rendered as a comment)
    Unsupported { what: &'static str },
    /// boundary marker: outputs-only D2H + frees begin here
    EpilogueBegin,
    /// §4.1: one updated property returns to the host
    CopyOut { slot: u32 },
    FreeProp { slot: u32 },
    FreeFlag,
    FreeGraph,
}

/// Walks the function body in the exact order of `ir::collect_kernels`,
/// producing the [`HostOp`] tree plus the fixedPoint / BFS skeleton lists
/// (kernel ids are assigned positionally, so the walk must mirror the IR
/// kernel schedule statement for statement). The walk also lowers each
/// kernel *body* to [`KernelOp`]s right here — the only place that knows the
/// fixedPoint OR-flag and BFS-sweep context a body is launched under.
struct HostLower<'a> {
    tf: &'a TypedFunction,
    props: &'a PropTable,
    next_kernel: usize,
    fixed_points: Vec<FixedPointPlan>,
    bfs_loops: Vec<BfsPlan>,
    /// lowered device bodies, keyed by kernel id
    bodies: Vec<(usize, KernelBody)>,
}

impl HostLower<'_> {
    fn take_kernel(&mut self) -> usize {
        let k = self.next_kernel;
        self.next_kernel += 1;
        k
    }

    /// Lower one device body under the given launch context and file it
    /// against its kernel id.
    fn lower_body(
        &mut self,
        kernel: usize,
        thread_var: &str,
        guard: Option<&Expr>,
        body: &[Stmt],
        bfs: Option<BfsDir>,
        or_flag: bool,
    ) {
        let cx = KernelLower { tf: self.tf, props: self.props, bfs, or_flag };
        let kb = KernelBody {
            thread_var: thread_var.to_string(),
            guard: guard.map(|g| simplify_bool_cmp(&resolve_filter(g, thread_var, self.tf))),
            ops: lower_kernel_body(body, &cx),
        };
        self.bodies.push((kernel, kb));
    }

    fn block(&mut self, b: &[Stmt], or_flag: bool) -> Vec<HostOp> {
        let mut out = Vec::new();
        for s in b {
            self.stmt(s, or_flag, &mut out);
        }
        out
    }

    fn stmt(&mut self, s: &Stmt, or_flag: bool, out: &mut Vec<HostOp>) {
        match s {
            // device-prop declarations become AllocProp ops in the prologue
            Stmt::Decl { ty, .. } if ty.is_prop() => {}
            Stmt::Decl { ty, name, init, .. } => out.push(HostOp::DeclScalar {
                name: name.clone(),
                ty: ScalarTy::of(ty),
                init: init.clone(),
            }),
            Stmt::Assign { target, value, .. } => match target {
                LValue::Var(v) => match self.props.slot(v) {
                    Some(dst) if !self.props.meta(dst).edge => {
                        // whole-property assignment: device-side copy when the
                        // source is a property too; anything else is dropped,
                        // matching the old emitters
                        let src = match value {
                            Expr::Var(s) => self.props.slot(s),
                            _ => None,
                        };
                        if let Some(src) = src {
                            out.push(HostOp::CopyProp { dst, src });
                        }
                    }
                    _ => out.push(HostOp::AssignScalar {
                        name: v.clone(),
                        value: value.clone(),
                    }),
                },
                LValue::Prop { obj, prop } => {
                    if let Some(slot) = self.props.slot(prop) {
                        out.push(HostOp::SetElement {
                            slot,
                            index: obj.clone(),
                            value: value.clone(),
                        });
                    }
                }
            },
            Stmt::Reduce { target, op, value, .. } => {
                if let LValue::Var(v) = target {
                    out.push(HostOp::ReduceScalar {
                        name: v.clone(),
                        op: *op,
                        value: value.clone(),
                    });
                }
            }
            Stmt::AttachNodeProperty { inits, .. } => {
                let kernel = self.take_kernel();
                let inits = inits
                    .iter()
                    .filter_map(|(p, e)| self.props.slot(p).map(|s| (s, e.clone())))
                    .collect();
                out.push(HostOp::InitProps { kernel, inits });
            }
            Stmt::For { parallel: true, iter, body, .. } => {
                let kernel = self.take_kernel();
                self.lower_body(kernel, &iter.var, iter.filter.as_ref(), body, None, or_flag);
                out.push(HostOp::Launch { kernel });
            }
            Stmt::For { parallel: false, iter, body, .. } => {
                let set = match &iter.source {
                    IterSource::Set { set } => set.clone(),
                    _ => "g.nodes()".to_string(),
                };
                let body = self.block(body, or_flag);
                out.push(HostOp::SeqFor { var: iter.var.clone(), set, body });
            }
            Stmt::IterateBFS { var, from, body, reverse, .. } => {
                let fwd = self.take_kernel();
                // sweep bodies run outside the fixedPoint flag protocol: the
                // BFS skeleton owns its own convergence word
                self.lower_body(fwd, var, None, body, Some(BfsDir::Forward), false);
                let rev = reverse.as_ref().map(|(cond, rbody)| {
                    let rk = self.take_kernel();
                    self.lower_body(rk, var, Some(cond), rbody, Some(BfsDir::Reverse), false);
                    rk
                });
                let index = self.bfs_loops.len();
                self.bfs_loops.push(BfsPlan { fwd, rev, level: self.props.slot("level") });
                out.push(HostOp::Bfs { index, var: var.clone(), from: from.clone() });
            }
            Stmt::FixedPoint { var, cond, body, .. } => {
                let flag_name = crate::ir::or_flag_prop(cond).unwrap_or_default();
                let index = self.fixed_points.len();
                self.fixed_points
                    .push(FixedPointPlan { flag: self.props.slot(&flag_name), flag_name });
                let body = self.block(body, true);
                out.push(HostOp::FixedPoint { index, var: var.clone(), body });
            }
            Stmt::DoWhile { body, cond, .. } => {
                out.push(HostOp::DoWhile { body: self.block(body, or_flag), cond: cond.clone() })
            }
            Stmt::While { cond, body, .. } => {
                out.push(HostOp::While { cond: cond.clone(), body: self.block(body, or_flag) })
            }
            Stmt::If { cond, then, els, .. } => out.push(HostOp::If {
                cond: cond.clone(),
                then: self.block(then, or_flag),
                els: els.as_ref().map(|e| self.block(e, or_flag)),
            }),
            Stmt::Return { value, .. } => out.push(HostOp::Return { value: value.clone() }),
            Stmt::MinMaxAssign { .. } => {
                out.push(HostOp::Unsupported { what: "Min/Max outside a parallel loop" })
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The device plan
// ---------------------------------------------------------------------------

/// The complete backend-neutral lowering of one DSL function. See the module
/// docs for what each piece replaces in the old per-backend emitters.
#[derive(Clone, Debug)]
pub struct DevicePlan {
    /// DSL function name (kernel names derive from it)
    pub func: String,
    /// canonical property slot table (shared with the interpreter)
    pub props: PropTable,
    pub host_params: Vec<HostParam>,
    /// CSR arrays needed on the device (reverse CSR only when some kernel
    /// pulls over in-edges)
    pub graph_arrays: Vec<GraphArray>,
    /// property slots device-resident for the whole function, slot order
    pub device_resident: Vec<u32>,
    /// property slots returning to the host at exit (outputs-only D2H)
    pub outputs: Vec<u32>,
    pub kernels: Vec<KernelPlan>,
    /// fixedPoint skeletons in program order
    pub fixed_points: Vec<FixedPointPlan>,
    /// iterateInBFS skeletons in program order
    pub bfs_loops: Vec<BfsPlan>,
    /// per-kernel traversal-schedule decisions (push/pull/delta)
    pub schedule: SchedulePlan,
    /// the complete host-statement schedule (prologue, body, epilogue);
    /// renderers consume this instead of walking the AST for host syntax
    pub host_ops: Vec<HostOp>,
}

impl DevicePlan {
    /// Lower one IR program into the backend-neutral plan. A program the
    /// lowering cannot handle yields a spanned [`DslError`] — user-reachable
    /// paths must diagnose, not panic.
    pub fn build(ir: &IrProgram) -> Result<DevicePlan, DslError> {
        let tf = &ir.tf;
        let mut props = PropTable::build(tf);

        let mut host_params = Vec::with_capacity(tf.func.params.len());
        for p in &tf.func.params {
            host_params.push(match &p.ty {
                Type::Graph => HostParam::Graph { name: p.name.clone() },
                Type::PropNode(_) | Type::PropEdge(_) => HostParam::Prop {
                    slot: props.slot(&p.name).ok_or_else(|| {
                        DslError::at(
                            p.span,
                            &format!("property parameter `{}` has no lowerable slot", p.name),
                        )
                    })?,
                },
                Type::SetN(_) => HostParam::Set { name: p.name.clone() },
                t => HostParam::Scalar { name: p.name.clone(), ty: ScalarTy::of(t) },
            });
        }

        let mut device_resident: Vec<u32> = ir
            .transfer
            .device_resident_props
            .iter()
            .filter_map(|n| props.slot(n))
            .collect();
        device_resident.sort_unstable();
        device_resident.dedup();

        let mut outputs: Vec<u32> =
            ir.transfer.outputs.iter().filter_map(|n| props.slot(n)).collect();
        outputs.sort_unstable();
        outputs.dedup();

        let mut kernels: Vec<KernelPlan> =
            ir.kernels.iter().map(|k| kernel_plan(ir, &props, k)).collect();

        let mut hl = HostLower {
            tf,
            props: &props,
            next_kernel: 0,
            fixed_points: Vec::new(),
            bfs_loops: Vec::new(),
            bodies: Vec::new(),
        };
        let mut body_ops = hl.block(&tf.func.body, false);
        // hard assert (one usize compare per build): the host walk must
        // mirror `ir::collect_kernels` exactly, or every downstream kernel id
        // would be silently shifted
        assert_eq!(hl.next_kernel, ir.kernels.len(), "host walk drifted from kernel schedule");
        let HostLower { fixed_points, bfs_loops, bodies, .. } = hl;
        for (id, body) in bodies {
            kernels[id].atomic_props = body.atomic_prop_slots();
            kernels[id].body = Some(body);
        }

        // BFS level save/restore repair: the generated BFS skeleton reuses a
        // *declared* `level` property as its discovery buffer and seeds it
        // with -1, clobbering whatever the program stored there (bfs.sp
        // attaches INF so unreachable vertices keep it — the interpreter
        // honors that). Repair it at the plan level so all renderers and the
        // plan executor inherit the fix: snapshot the buffer into a synthetic
        // scratch right before the skeleton, then one restore launch writes
        // the saved value back into every vertex the sweep never discovered
        // (level == -1). Discovered vertices keep their hop counts.
        for (bfs_index, b) in bfs_loops.iter().enumerate() {
            let Some(lvl) = b.level else { continue };
            let level_meta = props.meta(lvl).clone();
            let save_name = format!("{}_bfs_save", level_meta.name);
            let save = props.push_synthetic(&save_name, level_meta.ty, level_meta.edge);
            device_resident.push(save); // max slot so far: the vec stays sorted
            let id = kernels.len();
            let body = KernelBody {
                thread_var: "v".to_string(),
                guard: Some(Expr::Binary {
                    op: BinOp::Eq,
                    lhs: Box::new(Expr::Prop { obj: "v".to_string(), prop: level_meta.name }),
                    rhs: Box::new(Expr::IntLit(-1)),
                }),
                ops: vec![KernelOp::AssignProp {
                    slot: lvl,
                    obj: "v".to_string(),
                    value: Expr::Prop { obj: "v".to_string(), prop: save_name },
                }],
            };
            kernels.push(KernelPlan {
                id,
                kind: KernelKind::VertexParallel,
                name: format!("{}_bfs_restore_kernel_{id}", tf.func.name),
                in_host_loop: false,
                props: vec![lvl, save],
                uses_in_edges: false,
                reductions: Vec::new(),
                scalar_params: Vec::new(),
                copy_in: Vec::new(),
                copy_out: Vec::new(),
                defer_to_loop_exit: false,
                body: Some(body),
                atomic_props: Vec::new(),
                pull_body: None,
                synthetic: true,
            });
            let inserted = insert_bfs_repair(&mut body_ops, bfs_index, save, lvl, id);
            debug_assert!(inserted, "bfs[{bfs_index}] op missing from host schedule");
        }

        // Schedule pass: decide per kernel which traversal directions it can
        // run in. A pull variant flips a host-loop relaxation onto the
        // reverse CSR, so it must run before `graph_arrays` is fixed below.
        let mut choices = Vec::with_capacity(kernels.len());
        for k in &mut kernels {
            let (push_only, delta_eligible) = match &k.body {
                None => (Some(PushOnly::Init), false),
                Some(b) => {
                    let weighted = relax_shape(b);
                    if !k.in_host_loop {
                        (Some(PushOnly::NotIterated), false)
                    } else if let Some(pull) = pull_variant(b) {
                        k.pull_body = Some(pull);
                        k.uses_in_edges = true;
                        (None, false)
                    } else {
                        match weighted {
                            Some(true) => (Some(PushOnly::Weighted), true),
                            _ => (Some(PushOnly::Shape), false),
                        }
                    }
                }
            };
            choices.push(ScheduleChoice { kernel: k.id, push_only, delta_eligible });
        }
        let schedule = SchedulePlan {
            choices,
            bfs_direction_optimized: !bfs_loops.is_empty(),
        };

        let mut graph_arrays = vec![GraphArray::Offsets, GraphArray::EdgeList];
        if kernels.iter().any(|k| k.uses_in_edges) {
            graph_arrays.push(GraphArray::RevOffsets);
            graph_arrays.push(GraphArray::SrcList);
        }

        // a body ending in `return <scalar>` (e.g. TC) must run the epilogue
        // first, or every free would be emitted as unreachable code
        let trailing_return = match body_ops.last() {
            Some(HostOp::Return { .. }) => body_ops.pop(),
            _ => None,
        };

        // prologue: dims, graph H2D, buffer + flag allocation, launch dims
        let mut host_ops = vec![HostOp::DeclDims, HostOp::GraphToDevice];
        host_ops.extend(device_resident.iter().map(|&slot| HostOp::AllocProp { slot }));
        host_ops.push(HostOp::AllocFlag);
        host_ops.push(HostOp::LaunchSetup);
        host_ops.extend(body_ops);
        // epilogue: outputs-only D2H (§4.1), then every alloc's matching free
        host_ops.push(HostOp::EpilogueBegin);
        host_ops.extend(outputs.iter().map(|&slot| HostOp::CopyOut { slot }));
        host_ops.extend(device_resident.iter().map(|&slot| HostOp::FreeProp { slot }));
        host_ops.push(HostOp::FreeFlag);
        host_ops.push(HostOp::FreeGraph);
        host_ops.extend(trailing_return);

        Ok(DevicePlan {
            func: tf.func.name.clone(),
            props,
            host_params,
            graph_arrays,
            device_resident,
            outputs,
            kernels,
            fixed_points,
            bfs_loops,
            schedule,
            host_ops,
        })
    }

    pub fn meta(&self, slot: u32) -> &PropMeta {
        self.props.meta(slot)
    }

    pub fn prop_name(&self, slot: u32) -> &str {
        &self.props.meta(slot).name
    }

    /// Machine type of a property by name (I32 when unknown, matching the
    /// old emitters' fallback).
    pub fn prop_ty_of(&self, name: &str) -> ScalarTy {
        self.props.slot(name).map(|s| self.props.meta(s).ty).unwrap_or(ScalarTy::I32)
    }

    /// Rendered type of a property by name, through a backend's map.
    pub fn c_ty_of(&self, name: &str, map: &TypeMap) -> &'static str {
        map.name(self.prop_ty_of(name))
    }

    /// Rendered type of a property slot, through a backend's map.
    pub fn c_ty(&self, slot: u32, map: &TypeMap) -> &'static str {
        map.name(self.props.meta(slot).ty)
    }

    /// Output property names in slot order (JAX buffer bindings).
    pub fn output_names(&self) -> Vec<&str> {
        self.outputs.iter().map(|&s| self.props.meta(s).name.as_str()).collect()
    }

    /// Is `name` a declared *node* property? Renderers use this to classify
    /// whole-property assignment targets (`modified = modified_nxt`).
    pub fn is_node_prop(&self, name: &str) -> bool {
        matches!(self.props.slot(name), Some(s) if !self.props.meta(s).edge)
    }

    /// Launch-site argument name for a kernel parameter — identical across
    /// the pointer-passing backends (CUDA, OpenCL), so it lives here.
    pub fn launch_arg(&self, p: &KernelParam) -> String {
        match p {
            KernelParam::NumNodes => "V".to_string(),
            KernelParam::Graph(a) => a.device_name().to_string(),
            KernelParam::Prop(s) => format!("gpu_{}", self.prop_name(*s)),
            KernelParam::ReductionCell { name, .. } => format!("d_{name}"),
            KernelParam::Scalar { name, .. } => name.clone(),
            KernelParam::OrFlag => "gpu_finished".to_string(),
        }
    }

    /// The host function signature shared by the C-family backends.
    pub fn host_signature(&self, map: &TypeMap) -> Vec<String> {
        self.host_params
            .iter()
            .map(|p| match p {
                HostParam::Graph { name } => format!("graph& {name}"),
                HostParam::Prop { slot } => {
                    let m = self.props.meta(*slot);
                    format!("{}* {}", map.name(m.ty), m.name)
                }
                HostParam::Set { name } => format!("std::set<int>& {name}"),
                HostParam::Scalar { name, ty } => format!("{} {name}", map.name(*ty)),
            })
            .collect()
    }

    /// Stable, backend-neutral description of the plan. Every text renderer
    /// embeds this as a comment block; `tests/plan_numbering.rs` asserts it
    /// is byte-identical across the four backends.
    pub fn manifest(&self) -> Vec<String> {
        let mut out = Vec::new();
        out.push(format!(
            "==== device plan: {} ({} buffers, {} kernels) ====",
            self.func,
            self.props.len(),
            self.kernels.len()
        ));
        for (i, m) in self.props.metas().iter().enumerate() {
            let mut tags = vec![if m.edge { "edge" } else { "node" }];
            if m.param {
                tags.push("param");
            }
            if m.synthetic {
                tags.push("synthetic");
            }
            if self.outputs.contains(&(i as u32)) {
                tags.push("output");
            }
            out.push(format!(
                "buffer[{i}] {} : {} ({})",
                m.name,
                TypeMap::C.name(m.ty),
                tags.join(", ")
            ));
        }
        for k in &self.kernels {
            out.push(format!(
                "kernel[{}] {} {}{}",
                k.id,
                kind_token(&k.kind),
                k.name,
                if k.in_host_loop { " [host-loop]" } else { "" }
            ));
        }
        for (i, f) in self.fixed_points.iter().enumerate() {
            out.push(format!("fixedPoint[{i}] flag=`{}`", f.flag_name));
        }
        for (i, b) in self.bfs_loops.iter().enumerate() {
            match b.rev {
                Some(r) => out.push(format!("bfs[{i}] fwd=kernel[{}] rev=kernel[{}]", b.fwd, r)),
                None => out.push(format!("bfs[{i}] fwd=kernel[{}]", b.fwd)),
            }
        }
        out.push("==== end device plan ====".to_string());
        out
    }

    /// Stable, backend-neutral description of the host-statement schedule.
    /// Every text renderer embeds this as a comment block right after the
    /// device-plan manifest; `tests/host_schedule_conformance.rs` asserts it
    /// is byte-identical across all five backends — the proof that every
    /// backend's host section is derived from the same [`HostOp`] sequence.
    pub fn host_manifest(&self) -> Vec<String> {
        let mut out = vec![format!("==== host schedule: {} ====", self.func)];
        self.host_manifest_block(&self.host_ops, 0, false, &mut out);
        out.push("==== end host schedule ====".to_string());
        out
    }

    fn host_manifest_block(
        &self,
        ops: &[HostOp],
        depth: usize,
        in_fixed_point: bool,
        out: &mut Vec<String>,
    ) {
        let pad = "  ".repeat(depth);
        let buf = |s: u32| format!("buffer[{s}] {}", self.prop_name(s));
        for op in ops {
            match op {
                HostOp::DeclDims => out.push(format!("{pad}decl-dims")),
                HostOp::GraphToDevice => {
                    out.push(format!("{pad}graph-h2d ({} arrays)", self.graph_arrays.len()))
                }
                HostOp::AllocProp { slot } => out.push(format!("{pad}alloc {}", buf(*slot))),
                HostOp::AllocFlag => out.push(format!("{pad}alloc or-flag")),
                HostOp::LaunchSetup => out.push(format!("{pad}launch-setup")),
                HostOp::DeclScalar { name, ty, init } => {
                    let t = TypeMap::C.name(*ty);
                    match init {
                        Some(e) => out.push(format!(
                            "{pad}decl {name} : {t} = {}",
                            neutral_expr(e)
                        )),
                        None => out.push(format!("{pad}decl {name} : {t}")),
                    }
                }
                HostOp::AssignScalar { name, value } => {
                    out.push(format!("{pad}assign {name} = {}", neutral_expr(value)))
                }
                HostOp::CopyProp { dst, src } => {
                    out.push(format!("{pad}copy-prop {} <- {}", buf(*dst), buf(*src)))
                }
                HostOp::SetElement { slot, index, value } => out.push(format!(
                    "{pad}set {}[{index}] = {}",
                    buf(*slot),
                    neutral_expr(value)
                )),
                HostOp::ReduceScalar { name, op, value } => out.push(format!(
                    "{pad}reduce {name} {} {}",
                    op.symbol(),
                    neutral_expr(value)
                )),
                HostOp::InitProps { kernel, inits } => {
                    let names: Vec<&str> =
                        inits.iter().map(|(s, _)| self.prop_name(*s)).collect();
                    out.push(format!("{pad}init kernel[{kernel}] {{{}}}", names.join(", ")))
                }
                HostOp::Launch { kernel, .. } => out.push(format!(
                    "{pad}launch kernel[{kernel}] {}{}",
                    self.kernels[*kernel].name,
                    if in_fixed_point { " [+or-flag]" } else { "" }
                )),
                HostOp::SeqFor { var, set, body } => {
                    out.push(format!("{pad}for {var} in {set} {{"));
                    self.host_manifest_block(body, depth + 1, in_fixed_point, out);
                    out.push(format!("{pad}}}"));
                }
                HostOp::FixedPoint { index, var, body } => {
                    out.push(format!(
                        "{pad}fixedPoint[{index}] ({var}) flag=`{}` {{",
                        self.fixed_points[*index].flag_name
                    ));
                    self.host_manifest_block(body, depth + 1, true, out);
                    out.push(format!("{pad}}}"));
                }
                HostOp::Bfs { index, var, from } => {
                    let b = &self.bfs_loops[*index];
                    let rev = match b.rev {
                        Some(r) => format!(" rev=kernel[{r}]"),
                        None => String::new(),
                    };
                    out.push(format!(
                        "{pad}bfs[{index}] fwd=kernel[{}]{rev} ({var} from {from})",
                        b.fwd
                    ));
                }
                HostOp::DoWhile { body, cond } => {
                    out.push(format!("{pad}do {{"));
                    self.host_manifest_block(body, depth + 1, in_fixed_point, out);
                    out.push(format!("{pad}}} while {}", neutral_expr(cond)));
                }
                HostOp::While { cond, body } => {
                    out.push(format!("{pad}while {} {{", neutral_expr(cond)));
                    self.host_manifest_block(body, depth + 1, in_fixed_point, out);
                    out.push(format!("{pad}}}"));
                }
                HostOp::If { cond, then, els } => {
                    out.push(format!("{pad}if {} {{", neutral_expr(cond)));
                    self.host_manifest_block(then, depth + 1, in_fixed_point, out);
                    if let Some(e) = els {
                        out.push(format!("{pad}}} else {{"));
                        self.host_manifest_block(e, depth + 1, in_fixed_point, out);
                    }
                    out.push(format!("{pad}}}"));
                }
                HostOp::Return { value } => {
                    out.push(format!("{pad}return {}", neutral_expr(value)))
                }
                HostOp::Unsupported { what } => out.push(format!("{pad}unsupported: {what}")),
                HostOp::EpilogueBegin => out.push(format!("{pad}epilogue")),
                HostOp::CopyOut { slot } => out.push(format!("{pad}copy-out {}", buf(*slot))),
                HostOp::FreeProp { slot } => out.push(format!("{pad}free {}", buf(*slot))),
                HostOp::FreeFlag => out.push(format!("{pad}free or-flag")),
                HostOp::FreeGraph => out.push(format!("{pad}free graph")),
            }
        }
    }

    /// Stable, backend-neutral description of every lowered kernel body —
    /// the device-side twin of [`DevicePlan::host_manifest`]. Every text
    /// renderer embeds this as a third comment block;
    /// `tests/host_schedule_conformance.rs` asserts it is byte-identical
    /// across all seven backends, which is the proof that kernel emission is
    /// one lowering plus per-backend spellings.
    pub fn kernel_manifest(&self) -> Vec<String> {
        let mut out = vec![format!(
            "==== kernel ops: {} ({} kernels) ====",
            self.func,
            self.kernels.len()
        )];
        for k in &self.kernels {
            match &k.body {
                None => out.push(format!(
                    "kernel[{}] {} {} (inits on host schedule)",
                    k.id,
                    kind_token(&k.kind),
                    k.name
                )),
                Some(b) => {
                    let guard = match &b.guard {
                        Some(g) => format!(" guard={}", neutral_expr(g)),
                        None => String::new(),
                    };
                    let atomics = if k.atomic_props.is_empty() {
                        String::new()
                    } else {
                        let names: Vec<&str> =
                            k.atomic_props.iter().map(|&s| self.prop_name(s)).collect();
                        format!(" atomics={{{}}}", names.join(", "))
                    };
                    out.push(format!(
                        "kernel[{}] {} {} thread={}{guard}{atomics} {{",
                        k.id,
                        kind_token(&k.kind),
                        k.name,
                        b.thread_var
                    ));
                    self.kernel_ops_block(&b.ops, 1, &mut out);
                    out.push("}".to_string());
                }
            }
        }
        out.push("==== end kernel ops ====".to_string());
        out
    }

    /// Stable, backend-neutral description of the traversal-schedule
    /// decisions — the fourth manifest block. One line per kernel records
    /// its direction verdict (and why pull is unavailable, when it is), and
    /// derived pull bodies are printed in full so the re-orientation itself
    /// is pinned. `tests/host_schedule_conformance.rs` asserts the block is
    /// byte-identical across all seven text backends.
    pub fn schedule_manifest(&self) -> Vec<String> {
        let mut out = vec![format!("==== schedule plan: {} ====", self.func)];
        out.push(format!(
            "bfs: {}",
            if self.schedule.bfs_direction_optimized {
                "direction-optimizing (interp switches push/pull per level)"
            } else {
                "none"
            }
        ));
        for c in &self.schedule.choices {
            let k = &self.kernels[c.kernel];
            let dir = match c.push_only {
                Some(r) => format!("push ({})", r.token()),
                None => "push+pull (runtime switch `STARPLAT_DIRECTION`)".to_string(),
            };
            let delta =
                if c.delta_eligible { " delta=eligible (`STARPLAT_DELTA`)" } else { "" };
            out.push(format!("kernel[{}] {} : {dir}{delta}", k.id, k.name));
            if let Some(b) = &k.pull_body {
                out.push(format!("  pull thread={} {{", b.thread_var));
                self.kernel_ops_block(&b.ops, 2, &mut out);
                out.push("  }".to_string());
            }
        }
        out.push("==== end schedule plan ====".to_string());
        out
    }

    fn kernel_ops_block(&self, ops: &[KernelOp], depth: usize, out: &mut Vec<String>) {
        let pad = "  ".repeat(depth);
        let buf = |s: u32| format!("buffer[{s}] {}", self.prop_name(s));
        for op in ops {
            match op {
                KernelOp::Decl { name, ty, init } => {
                    let t = TypeMap::C.name(*ty);
                    match init {
                        Some(e) => {
                            out.push(format!("{pad}decl {name} : {t} = {}", neutral_expr(e)))
                        }
                        None => out.push(format!("{pad}decl {name} : {t}")),
                    }
                }
                KernelOp::AssignVar { name, value } => {
                    out.push(format!("{pad}assign {name} = {}", neutral_expr(value)))
                }
                KernelOp::AssignProp { slot, obj, value } => out.push(format!(
                    "{pad}store {}[{obj}] = {}",
                    buf(*slot),
                    neutral_expr(value)
                )),
                KernelOp::Reduce { cell, op, ty, value } => {
                    let loc = match cell {
                        KCell::Prop { slot, obj } => format!("{}[{obj}]", buf(*slot)),
                        KCell::Cell { name } => format!("cell `{name}`"),
                    };
                    out.push(format!(
                        "{pad}reduce {loc} {} {} : {}",
                        op.symbol(),
                        neutral_expr(value),
                        TypeMap::C.name(*ty)
                    ));
                }
                KernelOp::MinMax { kind, slot, obj, ty, compare, extra, or_flag } => {
                    let kw = if *kind == MinMax::Min { "min" } else { "max" };
                    let extras: Vec<String> = extra
                        .iter()
                        .map(|(t, v)| {
                            let t = match t {
                                KTarget::Var(n) => n.clone(),
                                KTarget::Prop { slot, obj } => format!("{}[{obj}]", buf(*slot)),
                            };
                            format!("{t} = {}", neutral_expr(v))
                        })
                        .collect();
                    out.push(format!(
                        "{pad}{kw} {}[{obj}] <- {} : {}{}{}",
                        buf(*slot),
                        neutral_expr(compare),
                        TypeMap::C.name(*ty),
                        if *or_flag { " [+or-flag]" } else { "" },
                        if extras.is_empty() {
                            String::new()
                        } else {
                            format!(" extras={{{}}}", extras.join("; "))
                        },
                    ));
                }
                KernelOp::NeighborLoop { var, of, reverse, bfs, filter, body } => {
                    let dir = if *reverse { "in" } else { "out" };
                    // both sweeps share the §3.4 BFS-DAG child filter
                    let bfs_tag = if bfs.is_some() { " bfs-dag" } else { "" };
                    let filt = match filter {
                        Some(f) => format!(" filter={}", neutral_expr(f)),
                        None => String::new(),
                    };
                    out.push(format!("{pad}for {var} in {dir}({of}){bfs_tag}{filt} {{"));
                    self.kernel_ops_block(body, depth + 1, out);
                    out.push(format!("{pad}}}"));
                }
                KernelOp::If { cond, then, els } => {
                    out.push(format!("{pad}if {} {{", neutral_expr(cond)));
                    self.kernel_ops_block(then, depth + 1, out);
                    if let Some(e) = els {
                        out.push(format!("{pad}}} else {{"));
                        self.kernel_ops_block(e, depth + 1, out);
                    }
                    out.push(format!("{pad}}}"));
                }
                KernelOp::Unsupported { what } => {
                    out.push(format!("{pad}unsupported: {what}"))
                }
            }
        }
    }
}

/// C-flavored expression rendering for the host manifest: backend-neutral
/// (no buffer-name styles) and with C spellings for literals, so the block
/// never leaks DSL tokens like `True` into generated files.
fn neutral_expr(e: &Expr) -> String {
    match e {
        Expr::IntLit(n) => n.to_string(),
        Expr::FloatLit(x) => {
            if x.fract() == 0.0 {
                format!("{x:.1}")
            } else {
                x.to_string()
            }
        }
        Expr::BoolLit(b) => b.to_string(),
        Expr::Inf => "INT_MAX".to_string(),
        Expr::Var(v) => v.clone(),
        Expr::Prop { obj, prop } => format!("{prop}[{obj}]"),
        Expr::Call { recv, name, args } => {
            let a: Vec<String> = args.iter().map(neutral_expr).collect();
            match recv {
                Some(r) => format!("{r}.{name}({})", a.join(", ")),
                None => format!("{name}({})", a.join(", ")),
            }
        }
        Expr::Unary { op, expr } => {
            let sym = match op {
                UnOp::Neg => "-",
                UnOp::Not => "!",
            };
            format!("{sym}{}", neutral_expr(expr))
        }
        Expr::Binary { op, lhs, rhs } => {
            format!("({} {} {})", neutral_expr(lhs), op.symbol(), neutral_expr(rhs))
        }
    }
}

fn kind_token(k: &KernelKind) -> &'static str {
    match k {
        KernelKind::InitProps => "init",
        KernelKind::VertexParallel => "vertex",
        KernelKind::BfsForward => "bfs-fwd",
        KernelKind::BfsReverse => "bfs-rev",
    }
}

fn kernel_name(func: &str, k: &Kernel) -> String {
    match k.kind {
        KernelKind::InitProps => format!("{func}_init_{}", k.id),
        KernelKind::VertexParallel => format!("{func}_kernel_{}", k.id),
        KernelKind::BfsForward => format!("{func}_bfs_kernel_{}", k.id),
        KernelKind::BfsReverse => format!("{func}_bfs_rev_kernel_{}", k.id),
    }
}

fn kernel_plan(ir: &IrProgram, props: &PropTable, k: &Kernel) -> KernelPlan {
    let tf = &ir.tf;
    let transfers = &ir.transfer.per_kernel[k.id];

    let mut pslots: Vec<u32> = k
        .uses
        .props_read
        .union(&k.uses.props_written)
        .filter_map(|n| props.slot(n))
        .collect();
    pslots.sort_unstable();
    pslots.dedup();

    let mut reductions: Vec<(String, ReduceOp, ScalarTy)> = Vec::new();
    for (r, op) in &k.uses.reductions {
        if reductions.iter().any(|(n, _, _)| n == r) {
            continue;
        }
        let ty = tf.vars.get(r).map(ScalarTy::of).unwrap_or(ScalarTy::I64);
        reductions.push((r.clone(), *op, ty));
    }

    // Scalars passed by value: declared non-prop, non-graph, non-set
    // variables the kernel reads — minus reduction targets, which already
    // travel as device cells.
    let scalar_params: Vec<(String, ScalarTy)> = transfers
        .scalar_params
        .iter()
        .filter(|s| !reductions.iter().any(|(n, _, _)| n == *s))
        .filter_map(|s| match tf.vars.get(s) {
            Some(ty) if !ty.is_prop() && !matches!(ty, Type::Graph | Type::SetN(_)) => {
                Some((s.clone(), ScalarTy::of(ty)))
            }
            _ => None,
        })
        .collect();

    KernelPlan {
        id: k.id,
        kind: k.kind.clone(),
        name: kernel_name(&tf.func.name, k),
        in_host_loop: k.in_host_loop,
        props: pslots,
        uses_in_edges: k.uses.uses_in_edges,
        reductions,
        scalar_params,
        copy_in: transfers.copy_in.iter().filter_map(|n| props.slot(n)).collect(),
        copy_out: transfers.copy_out.iter().filter_map(|n| props.slot(n)).collect(),
        defer_to_loop_exit: transfers.defer_to_loop_exit,
        body: None,
        atomic_props: Vec::new(),
        pull_body: None,
        synthetic: false,
    }
}

/// Wrap `bfs[bfs_index]` — wherever it sits in the host tree — with the
/// level-buffer snapshot before and the restore launch after. Returns true
/// once the op is found.
fn insert_bfs_repair(
    ops: &mut Vec<HostOp>,
    bfs_index: usize,
    save: u32,
    lvl: u32,
    repair: usize,
) -> bool {
    let mut i = 0;
    while i < ops.len() {
        if matches!(&ops[i], HostOp::Bfs { index, .. } if *index == bfs_index) {
            ops.insert(i, HostOp::CopyProp { dst: save, src: lvl });
            ops.insert(i + 2, HostOp::Launch { kernel: repair });
            return true;
        }
        match &mut ops[i] {
            HostOp::SeqFor { body, .. }
            | HostOp::FixedPoint { body, .. }
            | HostOp::DoWhile { body, .. }
            | HostOp::While { body, .. } => {
                if insert_bfs_repair(body, bfs_index, save, lvl, repair) {
                    return true;
                }
            }
            HostOp::If { then, els, .. } => {
                if insert_bfs_repair(then, bfs_index, save, lvl, repair) {
                    return true;
                }
                if let Some(e) = els {
                    if insert_bfs_repair(e, bfs_index, save, lvl, repair) {
                        return true;
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse;
    use crate::ir::lower;
    use crate::sema::check_function;

    fn plan_of(p: &str) -> DevicePlan {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(p);
        let src = std::fs::read_to_string(&path).unwrap();
        let fns = parse(&src).unwrap();
        let tf = check_function(&fns[0]).unwrap();
        DevicePlan::build(&lower(&tf)).expect("plan builds")
    }

    #[test]
    fn sssp_buffers_in_declaration_order() {
        let plan = plan_of("sssp.sp");
        let names: Vec<&str> = plan.props.metas().iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, vec!["dist", "weight", "modified", "modified_nxt"]);
        assert!(plan.props.meta(1).edge && plan.props.meta(1).param);
        assert_eq!(plan.outputs, vec![0]); // dist
        assert_eq!(plan.graph_arrays, vec![GraphArray::Offsets, GraphArray::EdgeList]);
    }

    #[test]
    fn sssp_relax_kernel_params_in_slot_order() {
        let plan = plan_of("sssp.sp");
        let relax = &plan.kernels[1];
        assert_eq!(relax.kind, KernelKind::VertexParallel);
        assert!(relax.in_host_loop && relax.defer_to_loop_exit);
        // props in interner order: dist(0), weight(1), modified(2), modified_nxt(3)
        assert_eq!(relax.props, vec![0, 1, 2, 3]);
        let params = relax.params(true);
        assert!(matches!(params[0], KernelParam::NumNodes));
        assert!(matches!(params.last(), Some(KernelParam::OrFlag)));
        // weight is owed an H2D copy before the first launch (§4.1)
        assert_eq!(relax.copy_in, vec![1]);
    }

    #[test]
    fn fixed_point_skeletons_carry_the_flag() {
        for p in ["sssp.sp", "cc.sp"] {
            let plan = plan_of(p);
            assert_eq!(plan.fixed_points.len(), 1, "{p}");
            let fp = &plan.fixed_points[0];
            assert_eq!(fp.flag_name, "modified", "{p}");
            assert_eq!(fp.flag, plan.props.slot("modified"), "{p}");
        }
    }

    #[test]
    fn bc_bfs_skeleton_binds_both_sweeps() {
        let plan = plan_of("bc.sp");
        assert_eq!(plan.bfs_loops.len(), 1);
        let b = &plan.bfs_loops[0];
        assert_eq!(plan.kernels[b.fwd].kind, KernelKind::BfsForward);
        assert_eq!(plan.kernels[b.rev.unwrap()].kind, KernelKind::BfsReverse);
        assert!(b.level.is_none(), "bc's level buffer is implicit");
        // bfs.sp declares `level`, so its skeleton binds the slot
        let bfs = plan_of("bfs.sp");
        assert_eq!(bfs.bfs_loops[0].level, bfs.props.slot("level"));
    }

    #[test]
    fn bfs_declared_level_gets_save_restore_repair() {
        // the BFS skeleton seeds its discovery buffer with -1; when that
        // buffer is a declared property (bfs.sp attaches INF to `level`),
        // the plan snapshots it before the skeleton and restores every
        // undiscovered vertex afterwards — interpreter semantics
        let plan = plan_of("bfs.sp");
        let lvl = plan.props.slot("level").unwrap();
        let save = plan.props.slot("level_bfs_save").expect("synthetic save buffer");
        let m = plan.props.meta(save);
        assert!(m.synthetic && !m.param);
        assert_eq!(m.ty, plan.props.meta(lvl).ty);
        assert!(plan.device_resident.contains(&save));
        let repair = plan.kernels.last().unwrap();
        assert!(repair.synthetic);
        assert_eq!(repair.props, vec![lvl, save]);
        let rb = repair.body.as_ref().unwrap();
        assert!(rb.guard.is_some(), "restore only rewrites undiscovered (-1) vertices");
        let bfs_at =
            plan.host_ops.iter().position(|o| matches!(o, HostOp::Bfs { .. })).unwrap();
        assert!(matches!(
            plan.host_ops[bfs_at - 1],
            HostOp::CopyProp { dst, src } if dst == save && src == lvl
        ));
        assert!(matches!(
            plan.host_ops[bfs_at + 1],
            HostOp::Launch { kernel } if kernel == repair.id
        ));
        // bc's level buffer is implicit — nothing to repair, nothing synthetic
        let bc = plan_of("bc.sp");
        assert!(bc.props.metas().iter().all(|m| !m.synthetic));
        assert!(bc.kernels.iter().all(|k| !k.synthetic));
    }

    // (host-schedule ↔ kernel-schedule agreement across all programs and
    // backends is pinned by tests/host_schedule_conformance.rs)

    #[test]
    fn sssp_host_schedule_shape() {
        let plan = plan_of("sssp.sp");
        let ops = &plan.host_ops;
        // prologue: dims, graph, one alloc per device-resident buffer, flag
        assert!(matches!(ops[0], HostOp::DeclDims));
        assert!(matches!(ops[1], HostOp::GraphToDevice));
        let allocs = ops
            .iter()
            .filter(|o| matches!(o, HostOp::AllocProp { .. }))
            .count();
        assert_eq!(allocs, plan.device_resident.len());
        // the fixedPoint body: relax launch, modified <- modified_nxt copy,
        // modified_nxt re-init
        let fp = ops
            .iter()
            .find_map(|o| match o {
                HostOp::FixedPoint { index, body, .. } => Some((index, body)),
                _ => None,
            })
            .expect("sssp has a fixedPoint op");
        assert_eq!(*fp.0, 0);
        assert!(fp.1.iter().any(|o| matches!(o, HostOp::Launch { kernel: 1, .. })));
        let (m, mn) =
            (plan.props.slot("modified").unwrap(), plan.props.slot("modified_nxt").unwrap());
        assert!(fp
            .1
            .iter()
            .any(|o| matches!(o, HostOp::CopyProp { dst, src } if *dst == m && *src == mn)));
        // epilogue: dist copy-out, every alloc freed, flag + graph freed
        let dist = plan.props.slot("dist").unwrap();
        assert!(ops.iter().any(|o| matches!(o, HostOp::CopyOut { slot } if *slot == dist)));
        let frees =
            ops.iter().filter(|o| matches!(o, HostOp::FreeProp { .. })).count();
        assert_eq!(frees, allocs);
        assert!(ops.iter().any(|o| matches!(o, HostOp::FreeFlag)));
        assert!(matches!(ops.last(), Some(HostOp::FreeGraph)));
    }

    #[test]
    fn tc_trailing_return_comes_after_the_epilogue_frees() {
        // tc.sp ends `return triangle_count;` — the schedule must run the
        // epilogue first or every backend would emit unreachable frees
        let plan = plan_of("tc.sp");
        let ops = &plan.host_ops;
        assert!(matches!(ops.last(), Some(HostOp::Return { .. })));
        let ret = ops.len() - 1;
        let free_graph = ops
            .iter()
            .position(|o| matches!(o, HostOp::FreeGraph))
            .expect("graph freed");
        assert!(free_graph < ret, "frees must precede the trailing return");
    }

    #[test]
    fn bc_host_schedule_nests_bfs_inside_source_loop() {
        let plan = plan_of("bc.sp");
        let seq = plan
            .host_ops
            .iter()
            .find_map(|o| match o {
                HostOp::SeqFor { set, body, .. } => Some((set, body)),
                _ => None,
            })
            .expect("bc iterates a source set");
        assert_eq!(seq.0, "sourceSet");
        assert!(seq.1.iter().any(|o| matches!(o, HostOp::SetElement { .. })));
        assert!(seq.1.iter().any(|o| matches!(o, HostOp::Bfs { index: 0, .. })));
        assert!(plan.bfs_loops[0].rev.is_some(), "reverse sweep bound on the skeleton");
    }

    #[test]
    fn kernel_bodies_are_plan_carried_with_context() {
        let plan = plan_of("sssp.sp");
        // init kernels carry no body; the relax kernel does
        assert!(plan.kernels[0].body.is_none());
        let relax = plan.kernels[1].body.as_ref().expect("relax body lowered");
        assert_eq!(relax.thread_var, "v");
        assert!(relax.guard.is_some(), "filter(modified == True) becomes the thread guard");
        // the Min construct knows it sits inside the fixedPoint (§4.1)
        let mut saw_min = false;
        for op in &relax.ops {
            op.visit(&mut |o| {
                if let KernelOp::MinMax { or_flag, .. } = o {
                    saw_min = true;
                    assert!(*or_flag);
                }
            });
        }
        assert!(saw_min);
        assert_eq!(plan.kernels[1].atomic_props, vec![plan.props.slot("dist").unwrap()]);
        // BFS sweeps get bodies too, tagged with their sweep direction
        let bc = plan_of("bc.sp");
        let b = &bc.bfs_loops[0];
        let fwd = bc.kernels[b.fwd].body.as_ref().expect("forward sweep body");
        assert!(matches!(&fwd.ops[0], KernelOp::NeighborLoop { bfs: Some(_), .. }));
        let rev = bc.kernels[b.rev.unwrap()].body.as_ref().expect("reverse sweep body");
        assert!(rev.guard.is_some(), "iterateInReverse condition becomes the guard");
    }

    #[test]
    fn kernel_manifest_is_deterministic_and_names_cells() {
        let a = plan_of("sssp.sp").kernel_manifest();
        let b = plan_of("sssp.sp").kernel_manifest();
        assert_eq!(a, b);
        assert!(a[0].contains("kernel ops: Compute_SSSP"));
        assert!(a.iter().any(|l| l.contains("min buffer[0] dist[nbr]")));
        assert!(a.iter().any(|l| l.contains("[+or-flag]")));
        // no DSL literal leaks into generated comment blocks
        assert!(a.iter().all(|l| !l.contains("True") && !l.contains("False")));
        assert_eq!(a.last().unwrap(), "==== end kernel ops ====");
        let tc = plan_of("tc.sp").kernel_manifest();
        assert!(tc.iter().any(|l| l.contains("reduce cell `triangle_count` += 1 : long long")));
    }

    #[test]
    fn host_manifest_is_deterministic_and_marks_or_flag_launches() {
        let a = plan_of("sssp.sp").host_manifest();
        let b = plan_of("sssp.sp").host_manifest();
        assert_eq!(a, b);
        assert!(a[0].contains("host schedule: Compute_SSSP"));
        assert!(a.iter().any(|l| l.contains("launch kernel[1]") && l.contains("[+or-flag]")));
        assert!(a.iter().any(|l| l.trim() == "epilogue"));
        // no DSL literal leaks into generated comment blocks
        assert!(a.iter().all(|l| !l.contains("True") && !l.contains("False")));
        assert_eq!(a.last().unwrap(), "==== end host schedule ====");
    }

    #[test]
    fn opencl_type_map_demotes_bool() {
        assert_eq!(TypeMap::OPENCL.name(ScalarTy::Bool), "int");
        assert_eq!(TypeMap::C.name(ScalarTy::Bool), "bool");
        assert_eq!(TypeMap::NUMPY.name(ScalarTy::F32), "float32");
        let plan = plan_of("sssp.sp");
        assert_eq!(plan.c_ty_of("modified", &TypeMap::OPENCL), "int");
        assert_eq!(plan.c_ty_of("modified", &TypeMap::C), "bool");
    }

    #[test]
    fn manifest_is_deterministic_and_complete() {
        let a = plan_of("sssp.sp").manifest();
        let b = plan_of("sssp.sp").manifest();
        assert_eq!(a, b);
        assert!(a[0].contains("device plan: Compute_SSSP"));
        assert!(a.iter().any(|l| l.contains("buffer[0] dist")));
        assert!(a.iter().any(|l| l.contains("fixedPoint[0] flag=`modified`")));
        assert_eq!(a.last().unwrap(), "==== end device plan ====");
    }

    #[test]
    fn cc_relax_gets_a_pull_body_and_the_reverse_csr() {
        let plan = plan_of("cc.sp");
        let relax = plan
            .kernels
            .iter()
            .find(|k| k.in_host_loop && k.body.is_some())
            .expect("cc has a host-loop relax kernel");
        let pull = relax.pull_body.as_ref().expect("weight-free relax pulls");
        assert!(matches!(&pull.ops[0], KernelOp::NeighborLoop { reverse: true, .. }));
        assert!(relax.uses_in_edges, "pull variant flips the kernel onto the reverse CSR");
        assert_eq!(
            plan.graph_arrays,
            vec![
                GraphArray::Offsets,
                GraphArray::EdgeList,
                GraphArray::RevOffsets,
                GraphArray::SrcList
            ],
            "graph H2D must ship the reverse CSR once a pull body exists"
        );
        let c = &plan.schedule.choices[relax.id];
        assert!(c.push_only.is_none() && !c.delta_eligible);
    }

    #[test]
    fn sssp_relax_is_push_only_but_delta_eligible() {
        let plan = plan_of("sssp.sp");
        let c = &plan.schedule.choices[1];
        assert_eq!(c.push_only, Some(PushOnly::Weighted));
        assert!(c.delta_eligible);
        assert!(plan.kernels[1].pull_body.is_none());
        // and the decision must not drag the reverse CSR onto the device
        assert_eq!(plan.graph_arrays, vec![GraphArray::Offsets, GraphArray::EdgeList]);
    }

    #[test]
    fn schedule_manifest_is_deterministic_and_prints_pull_bodies() {
        let a = plan_of("cc.sp").schedule_manifest();
        let b = plan_of("cc.sp").schedule_manifest();
        assert_eq!(a, b);
        assert!(a[0].contains("schedule plan: Compute_CC"));
        assert!(a.iter().any(|l| l.contains("push+pull (runtime switch `STARPLAT_DIRECTION`)")));
        assert!(a.iter().any(|l| l.contains("for nbr in in(v)")), "pull body printed: {a:?}");
        assert_eq!(a.last().unwrap(), "==== end schedule plan ====");
        let s = plan_of("sssp.sp").schedule_manifest();
        assert!(s.iter().any(|l| l.contains("push (weighted (no rev_edge_id))")));
        assert!(s.iter().any(|l| l.contains("delta=eligible (`STARPLAT_DELTA`)")));
        let bfs = plan_of("bfs.sp").schedule_manifest();
        assert!(bfs[1].contains("direction-optimizing"));
    }

    #[test]
    fn kernel_ids_match_ir_schedule_positions() {
        for p in ["bc.sp", "pr.sp", "sssp.sp", "tc.sp", "cc.sp", "bfs.sp"] {
            let plan = plan_of(p);
            for (i, k) in plan.kernels.iter().enumerate() {
                assert_eq!(k.id, i, "{p}");
                // slot-order invariant on every parameter list
                let mut prev = None;
                for s in &k.props {
                    if let Some(q) = prev {
                        assert!(q < *s, "{p}: kernel {i} props unsorted");
                    }
                    prev = Some(*s);
                }
            }
        }
    }
}
