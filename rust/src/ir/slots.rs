//! Dense slot interning for name → index resolution.
//!
//! The paper's generated accelerator code never touches a symbol table at
//! run time: every property is an array, every scalar a kernel parameter.
//! The execution backends get the same treatment by interning names into
//! dense `u32` slots once, at lowering time. The interpreter's lowering pass
//! (`backends::interp::compile`) is the first consumer; the codegen backends
//! can reuse the same tables for buffer numbering (see ROADMAP open items).

use std::collections::HashMap;

/// An append-only name → dense-index table. Slots are handed out in
/// first-intern order, so interning in a deterministic walk order (params
/// first, then declaration order) yields stable slot numbering.
#[derive(Clone, Debug, Default)]
pub struct Interner {
    names: Vec<String>,
    index: HashMap<String, u32>,
}

impl Interner {
    pub fn new() -> Interner {
        Interner::default()
    }

    /// Intern `name`, returning its slot (existing or freshly assigned).
    pub fn intern(&mut self, name: &str) -> u32 {
        if let Some(&i) = self.index.get(name) {
            return i;
        }
        let i = self.names.len() as u32;
        self.names.push(name.to_string());
        self.index.insert(name.to_string(), i);
        i
    }

    /// Slot of an already-interned name.
    pub fn get(&self, name: &str) -> Option<u32> {
        self.index.get(name).copied()
    }

    /// Name for a slot (panics on out-of-range — slots are compiler-issued).
    pub fn name(&self, slot: u32) -> &str {
        &self.names[slot as usize]
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// All interned names in slot order.
    pub fn names(&self) -> &[String] {
        &self.names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_are_dense_and_stable() {
        let mut t = Interner::new();
        assert_eq!(t.intern("dist"), 0);
        assert_eq!(t.intern("weight"), 1);
        assert_eq!(t.intern("dist"), 0); // re-intern is idempotent
        assert_eq!(t.len(), 2);
        assert_eq!(t.name(1), "weight");
        assert_eq!(t.get("weight"), Some(1));
        assert_eq!(t.get("nope"), None);
        assert_eq!(t.names(), &["dist".to_string(), "weight".to_string()]);
    }
}
