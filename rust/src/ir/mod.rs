//! Intermediate representation: kernel extraction + the paper's §4 analyses.
//!
//! Rather than duplicating the AST, the IR is a *kernel schedule* layered on
//! the typed AST: every parallel construct (forall, attachNodeProperty,
//! iterateInBFS, the body of a fixedPoint) becomes a [`Kernel`] with
//! read/write/reduction sets and a host↔device transfer plan. The IR is then
//! lowered once more into the backend-neutral [`plan::DevicePlan`], which the
//! code generators (CUDA/OpenCL/SYCL/OpenACC/JAX) render and whose slot
//! tables the interpreter shares.

pub mod analyze;
pub mod kernel;
pub mod plan;
pub mod slots;
pub mod transfer;

use crate::dsl::ast::{Stmt, Type};
use crate::sema::TypedFunction;
use analyze::VarUse;

/// Scalar machine types used across backends (maps the DSL's C-like types).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScalarTy {
    I32,
    I64,
    F32,
    F64,
    Bool,
}

impl ScalarTy {
    pub fn of(t: &Type) -> ScalarTy {
        match t {
            Type::Int | Type::Node | Type::Edge => ScalarTy::I32,
            Type::Long => ScalarTy::I64,
            Type::Float => ScalarTy::F32,
            Type::Double => ScalarTy::F64,
            Type::Bool => ScalarTy::Bool,
            Type::PropNode(inner) | Type::PropEdge(inner) => ScalarTy::of(inner),
            _ => ScalarTy::I32,
        }
    }
    /// C type name, as emitted by the CUDA/OpenCL/SYCL backends.
    pub fn c_name(&self) -> &'static str {
        match self {
            ScalarTy::I32 => "int",
            ScalarTy::I64 => "long long",
            ScalarTy::F32 => "float",
            ScalarTy::F64 => "double",
            ScalarTy::Bool => "bool",
        }
    }
    /// numpy dtype name, emitted by the JAX backend.
    pub fn np_name(&self) -> &'static str {
        match self {
            ScalarTy::I32 => "int32",
            ScalarTy::I64 => "int64",
            ScalarTy::F32 => "float32",
            ScalarTy::F64 => "float64",
            ScalarTy::Bool => "bool_",
        }
    }
}

/// What kind of device kernel a statement turns into.
#[derive(Clone, Debug, PartialEq)]
pub enum KernelKind {
    /// `g.attachNodeProperty(p = e, ...)` — an N-wide initialization.
    InitProps,
    /// top-level `forall` — the paper's main vertex-parallel kernel.
    VertexParallel,
    /// `iterateInBFS` forward sweep (one kernel per level, host loop).
    BfsForward,
    /// `iterateInReverse` sweep.
    BfsReverse,
}

/// A device kernel extracted from the AST. `path` addresses the originating
/// statement: indices into nested statement lists from the function body.
#[derive(Clone, Debug)]
pub struct Kernel {
    pub id: usize,
    pub kind: KernelKind,
    pub path: Vec<usize>,
    /// variable use analysis of the kernel body
    pub uses: VarUse,
    /// true if the kernel sits inside a fixedPoint / host convergence loop
    pub in_host_loop: bool,
}

/// The lowered program: typed function + kernel schedule + transfer plan.
#[derive(Clone, Debug)]
pub struct IrProgram {
    pub tf: TypedFunction,
    pub kernels: Vec<Kernel>,
    pub transfer: transfer::TransferPlan,
}

pub fn lower(tf: &TypedFunction) -> IrProgram {
    let mut kernels = Vec::new();
    collect_kernels(&tf.func.body, &mut Vec::new(), false, &mut kernels);
    let transfer = transfer::plan(tf, &kernels);
    IrProgram { tf: tf.clone(), kernels, transfer }
}

fn collect_kernels(
    block: &[Stmt],
    path: &mut Vec<usize>,
    in_host_loop: bool,
    out: &mut Vec<Kernel>,
) {
    for (i, s) in block.iter().enumerate() {
        path.push(i);
        match s {
            Stmt::AttachNodeProperty { .. } => {
                let uses = analyze::stmt_uses(s);
                out.push(Kernel {
                    id: out.len(),
                    kind: KernelKind::InitProps,
                    path: path.clone(),
                    uses,
                    in_host_loop,
                });
            }
            Stmt::For { parallel: true, .. } => {
                // stmt-level analysis includes the forall's own filter.
                let uses = analyze::stmt_uses(s);
                out.push(Kernel {
                    id: out.len(),
                    kind: KernelKind::VertexParallel,
                    path: path.clone(),
                    uses,
                    in_host_loop,
                });
                // nested forall loops fold into the same kernel (the paper
                // maps the inner neighbor-forall onto the same GPU kernel)
            }
            Stmt::For { parallel: false, body, .. } => {
                // sequential host loop (e.g. `for (src in sourceSet)`)
                collect_kernels(body, path, in_host_loop, out);
            }
            Stmt::IterateBFS { body, reverse, .. } => {
                out.push(Kernel {
                    id: out.len(),
                    kind: KernelKind::BfsForward,
                    path: path.clone(),
                    uses: analyze::block_uses(body),
                    in_host_loop: true, // BFS is a host do-while over levels
                });
                if let Some((_, rbody)) = reverse {
                    out.push(Kernel {
                        id: out.len(),
                        kind: KernelKind::BfsReverse,
                        path: path.clone(),
                        uses: analyze::block_uses(rbody),
                        in_host_loop: true,
                    });
                }
            }
            Stmt::FixedPoint { body, .. } => {
                collect_kernels(body, path, true, out);
            }
            Stmt::DoWhile { body, .. } | Stmt::While { body, .. } => {
                collect_kernels(body, path, true, out);
            }
            Stmt::If { then, els, .. } => {
                collect_kernels(then, path, in_host_loop, out);
                if let Some(e) = els {
                    collect_kernels(e, path, in_host_loop, out);
                }
            }
            _ => {}
        }
        path.pop();
    }
}

/// Resolve a kernel path back to its statement.
pub fn stmt_at<'a>(body: &'a [Stmt], path: &[usize]) -> &'a Stmt {
    let mut cur: &Stmt = &body[path[0]];
    for &idx in &path[1..] {
        cur = match cur {
            Stmt::For { body, .. } => &body[idx],
            Stmt::FixedPoint { body, .. } => &body[idx],
            Stmt::DoWhile { body, .. } => &body[idx],
            Stmt::While { body, .. } => &body[idx],
            Stmt::IterateBFS { body, .. } => &body[idx],
            Stmt::If { then, .. } => &then[idx], // else-paths not addressed by kernels today
            other => panic!("bad kernel path segment into {other:?}"),
        };
    }
    cur
}

/// Detect the OR-reduction flag optimization opportunity (paper §4.1):
/// a fixedPoint whose convergence is `!someBoolProp` — the generated code
/// keeps ONE device flag instead of copying the whole prop array back.
pub fn or_flag_prop(cond: &crate::dsl::ast::Expr) -> Option<String> {
    use crate::dsl::ast::{Expr, UnOp};
    match cond {
        Expr::Unary { op: UnOp::Not, expr } => match &**expr {
            Expr::Var(p) => Some(p.clone()),
            _ => None,
        },
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::ast::ReduceOp;
    use crate::dsl::parser::parse;
    use crate::sema::check_function;

    fn lower_src(src: &str) -> IrProgram {
        let fns = parse(src).unwrap();
        let tf = check_function(&fns[0]).unwrap();
        lower(&tf)
    }

    fn lower_program(p: &str) -> IrProgram {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(p);
        let src = std::fs::read_to_string(&path).unwrap();
        lower_src(&src)
    }

    #[test]
    fn sssp_kernel_schedule() {
        let ir = lower_program("sssp.sp");
        let kinds: Vec<KernelKind> = ir.kernels.iter().map(|k| k.kind.clone()).collect();
        // attach, relax-forall (inside fixedPoint), attach (reset modified_nxt)
        assert_eq!(
            kinds,
            vec![KernelKind::InitProps, KernelKind::VertexParallel, KernelKind::InitProps]
        );
        assert!(ir.kernels[1].in_host_loop);
        assert!(!ir.kernels[0].in_host_loop);
        // the relax kernel reads dist/weight and writes dist/modified_nxt
        let u = &ir.kernels[1].uses;
        assert!(u.props_read.contains("dist"));
        assert!(u.props_read.contains("weight"));
        assert!(u.props_written.contains("dist"));
        assert!(u.props_written.contains("modified_nxt"));
    }

    #[test]
    fn bc_has_bfs_kernels() {
        let ir = lower_program("bc.sp");
        let kinds: Vec<KernelKind> = ir.kernels.iter().map(|k| k.kind.clone()).collect();
        assert!(kinds.contains(&KernelKind::BfsForward));
        assert!(kinds.contains(&KernelKind::BfsReverse));
    }

    #[test]
    fn tc_reduction_detected() {
        let ir = lower_program("tc.sp");
        assert_eq!(ir.kernels.len(), 1);
        let u = &ir.kernels[0].uses;
        assert!(u
            .reductions
            .iter()
            .any(|(t, op)| t == "triangle_count" && *op == ReduceOp::Add));
    }

    #[test]
    fn pr_kernel_inside_dowhile_is_host_loop() {
        let ir = lower_program("pr.sp");
        let vp: Vec<&Kernel> =
            ir.kernels.iter().filter(|k| k.kind == KernelKind::VertexParallel).collect();
        assert_eq!(vp.len(), 1);
        assert!(vp[0].in_host_loop);
    }

    #[test]
    fn stmt_at_resolves_paths() {
        let ir = lower_program("sssp.sp");
        for k in &ir.kernels {
            let s = stmt_at(&ir.tf.func.body, &k.path);
            match k.kind {
                KernelKind::InitProps => assert!(matches!(s, Stmt::AttachNodeProperty { .. })),
                KernelKind::VertexParallel => {
                    assert!(matches!(s, Stmt::For { parallel: true, .. }))
                }
                _ => {}
            }
        }
    }

    #[test]
    fn or_flag_detection() {
        let ir = lower_program("sssp.sp");
        let fp = ir
            .tf
            .func
            .body
            .iter()
            .find_map(|s| match s {
                Stmt::FixedPoint { cond, .. } => Some(cond.clone()),
                _ => None,
            })
            .expect("sssp has a fixedPoint");
        assert_eq!(or_flag_prop(&fp), Some("modified".to_string()));
    }

    #[test]
    fn scalar_ty_mapping() {
        assert_eq!(ScalarTy::of(&Type::Float), ScalarTy::F32);
        assert_eq!(ScalarTy::of(&Type::Long), ScalarTy::I64);
        assert_eq!(ScalarTy::of(&Type::PropNode(Box::new(Type::Double))), ScalarTy::F64);
        assert_eq!(ScalarTy::F32.c_name(), "float");
        assert_eq!(ScalarTy::I64.np_name(), "int64");
    }
}
