//! Host↔device transfer planning — the paper's §4 "Optimized Host-Device
//! Data Transfer" analysis, shared by all backends:
//!
//! - the (static) graph CSR arrays are copied to the device **once** at
//!   function entry, never back;
//! - properties read by a kernel are copied in before it (unless already
//!   device-resident), written properties are copied out only if the host
//!   (or a later host phase) consumes them;
//! - the fixedPoint `finished` flag ping-pongs host↔device each iteration
//!   (Figure 12);
//! - forall-local variables become device-only;
//! - the OR-reduction optimization replaces per-vertex `modified` copies
//!   with a single device flag word.

use super::analyze::VarUse;
use super::Kernel;
use crate::sema::TypedFunction;
use std::collections::BTreeSet;

/// Direction-annotated buffer list for one kernel launch.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KernelTransfers {
    /// properties to cudaMemcpy H2D before launch
    pub copy_in: Vec<String>,
    /// properties to cudaMemcpy D2H after the launch (or after the enclosing
    /// host loop finishes, see `defer_to_loop_exit`)
    pub copy_out: Vec<String>,
    /// scalar kernel parameters (passed by value)
    pub scalar_params: Vec<String>,
    /// scalar reduction cells living on the device (atomicAdd targets)
    pub reduction_cells: Vec<String>,
    /// copy-out may be deferred to the convergence-loop exit (§4.1): the
    /// property stays device-resident between iterations
    pub defer_to_loop_exit: bool,
}

/// Whole-function plan.
#[derive(Clone, Debug, Default)]
pub struct TransferPlan {
    /// graph arrays needed on device at entry (offsets/edges always; weights
    /// and reverse-CSR only when used)
    pub graph_arrays: Vec<String>,
    /// properties that live on the device for the whole function
    pub device_resident_props: Vec<String>,
    /// properties that must return to the host at function exit (outputs:
    /// they are propNode parameters, not locals)
    pub outputs: Vec<String>,
    /// per-kernel transfer lists (indexed by kernel id)
    pub per_kernel: Vec<KernelTransfers>,
    /// bool props eligible for the single-flag OR-reduction (§4.1)
    pub or_flag_props: Vec<String>,
}

pub fn plan(tf: &TypedFunction, kernels: &[Kernel]) -> TransferPlan {
    let mut union = VarUse::default();
    for k in kernels {
        union.scalars_read.extend(k.uses.scalars_read.iter().cloned());
        union.props_read.extend(k.uses.props_read.iter().cloned());
        union.props_written.extend(k.uses.props_written.iter().cloned());
        union.uses_is_an_edge |= k.uses.uses_is_an_edge;
        union.uses_in_edges |= k.uses.uses_in_edges;
    }

    // --- graph arrays -------------------------------------------------
    let mut graph_arrays = vec!["gpu_OA".to_string(), "gpu_edgeList".to_string()];
    if union.uses_in_edges {
        graph_arrays.push("gpu_rev_OA".to_string());
        graph_arrays.push("gpu_srcList".to_string());
    }
    // edge weights are modelled as a propEdge (e.g. `weight`), detected below.

    // --- device-resident properties ------------------------------------
    let all_props: BTreeSet<String> = union
        .props_read
        .iter()
        .chain(union.props_written.iter())
        .filter(|p| tf.node_props.contains_key(*p) || tf.edge_props.contains_key(*p))
        .cloned()
        .collect();
    let device_resident_props: Vec<String> = all_props.iter().cloned().collect();

    // outputs = property *parameters* written by some kernel
    let param_props: BTreeSet<String> = tf
        .func
        .params
        .iter()
        .filter(|p| p.ty.is_prop())
        .map(|p| p.name.clone())
        .collect();
    let outputs: Vec<String> = union
        .props_written
        .iter()
        .filter(|p| param_props.contains(*p))
        .cloned()
        .collect();

    // --- OR-flag candidates ---------------------------------------------
    let mut or_flag_props = Vec::new();
    for s in &tf.func.body {
        collect_or_flags(s, &mut or_flag_props);
    }

    // --- per-kernel lists -------------------------------------------------
    let mut per_kernel = Vec::with_capacity(kernels.len());
    let mut device_resident: BTreeSet<String> = BTreeSet::new();
    for k in kernels {
        let mut t = KernelTransfers::default();
        for p in &k.uses.props_read {
            if !tf.node_props.contains_key(p) && !tf.edge_props.contains_key(p) {
                continue;
            }
            if !device_resident.contains(p) {
                t.copy_in.push(p.clone());
                device_resident.insert(p.clone());
            }
        }
        for p in &k.uses.props_written {
            if !tf.node_props.contains_key(p) && !tf.edge_props.contains_key(p) {
                continue;
            }
            device_resident.insert(p.clone());
            if param_props.contains(p) {
                t.copy_out.push(p.clone());
            }
        }
        // scalar params: anything read that is a declared scalar variable
        t.scalar_params = k
            .uses
            .scalars_read
            .iter()
            .filter(|v| {
                tf.vars.get(*v).map(|ty| !ty.is_prop() && *ty != crate::dsl::ast::Type::Graph)
                    == Some(true)
            })
            .cloned()
            .collect();
        t.reduction_cells = k.uses.reductions.iter().map(|(v, _)| v.clone()).collect();
        // Kernels inside convergence loops keep their state device-side and
        // defer output copies until the loop exits (§4.1 / §4.3).
        t.defer_to_loop_exit = k.in_host_loop;
        per_kernel.push(t);
    }

    TransferPlan { graph_arrays, device_resident_props, outputs, per_kernel, or_flag_props }
}

fn collect_or_flags(s: &crate::dsl::ast::Stmt, out: &mut Vec<String>) {
    use crate::dsl::ast::Stmt;
    match s {
        Stmt::FixedPoint { cond, body, .. } => {
            if let Some(p) = super::or_flag_prop(cond) {
                out.push(p);
            }
            for st in body {
                collect_or_flags(st, out);
            }
        }
        Stmt::For { body, .. }
        | Stmt::DoWhile { body, .. }
        | Stmt::While { body, .. } => {
            for st in body {
                collect_or_flags(st, out);
            }
        }
        Stmt::If { then, els, .. } => {
            for st in then {
                collect_or_flags(st, out);
            }
            if let Some(e) = els {
                for st in e {
                    collect_or_flags(st, out);
                }
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {

    use crate::dsl::parser::parse;
    use crate::ir::lower;
    use crate::sema::check_function;

    fn plan_program(p: &str) -> crate::ir::IrProgram {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(p);
        let src = std::fs::read_to_string(&path).unwrap();
        let fns = parse(&src).unwrap();
        lower(&check_function(&fns[0]).unwrap())
    }

    #[test]
    fn sssp_plan_shapes() {
        let ir = plan_program("sssp.sp");
        let plan = &ir.transfer;
        // dist is an output (propNode param, written)
        assert!(plan.outputs.contains(&"dist".to_string()));
        // modified is the OR-flag candidate
        assert_eq!(plan.or_flag_props, vec!["modified".to_string()]);
        // the relax kernel defers copy-out (device-resident across iterations)
        assert!(plan.per_kernel[1].defer_to_loop_exit);
        // graph arrays copied once
        assert!(plan.graph_arrays.contains(&"gpu_OA".to_string()));
    }

    #[test]
    fn pr_needs_reverse_csr() {
        let ir = plan_program("pr.sp");
        assert!(ir.transfer.graph_arrays.contains(&"gpu_rev_OA".to_string()));
        assert!(ir.transfer.outputs.contains(&"pageRank".to_string()));
    }

    #[test]
    fn tc_has_reduction_cell_and_no_prop_outputs() {
        let ir = plan_program("tc.sp");
        assert!(ir.transfer.outputs.is_empty());
        assert_eq!(ir.transfer.per_kernel[0].reduction_cells, vec!["triangle_count".to_string()]);
    }

    #[test]
    fn soundness_every_device_read_is_resident() {
        // Property: for each kernel, every property it reads was either
        // copied in by this kernel or made resident by an earlier one.
        for p in ["bc.sp", "pr.sp", "sssp.sp", "tc.sp", "cc.sp", "bfs.sp"] {
            let ir = plan_program(p);
            let mut resident: std::collections::BTreeSet<String> = Default::default();
            for (k, t) in ir.kernels.iter().zip(&ir.transfer.per_kernel) {
                for c in &t.copy_in {
                    resident.insert(c.clone());
                }
                for r in &k.uses.props_read {
                    if ir.tf.node_props.contains_key(r) || ir.tf.edge_props.contains_key(r) {
                        assert!(
                            resident.contains(r),
                            "{p}: kernel {} reads non-resident {r}",
                            k.id
                        );
                    }
                }
                for w in &k.uses.props_written {
                    resident.insert(w.clone());
                }
            }
        }
    }
}
