//! Plain-text edge-list I/O.
//!
//! Format (compatible with SNAP-style lists plus an optional weight column):
//!
//! ```text
//! # comment lines start with '#' or '%'
//! <num_nodes>            (optional header; inferred from max id otherwise)
//! u v [w]
//! ```

use super::csr::{Graph, GraphBuilder, Node, Weight};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

pub fn load_edge_list(path: &Path) -> Result<Graph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    let mut edges: Vec<(Node, Node, Weight)> = Vec::new();
    let mut declared_nodes: Option<usize> = None;
    let mut max_id: Node = 0;
    for (lineno, line) in reader.lines().enumerate() {
        // every diagnostic carries file + 1-based line: "<path>:<line>: why"
        let at = || format!("{}:{}", path.display(), lineno + 1);
        let line = line.with_context(|| format!("{}: read error", at()))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let parts: Vec<&str> = t.split_whitespace().collect();
        match parts.len() {
            1 if declared_nodes.is_none() && edges.is_empty() => {
                declared_nodes =
                    Some(parts[0].parse().with_context(|| format!("{}: bad node count", at()))?);
            }
            2 | 3 => {
                // negative ids fail the unsigned parse and report here too
                let u: Node = parts[0].parse().with_context(|| format!("{}: bad src", at()))?;
                let v: Node = parts[1].parse().with_context(|| format!("{}: bad dst", at()))?;
                let w: Weight = match parts.get(2) {
                    None => 1,
                    Some(s) => match parse_weight(s) {
                        Ok(w) => w,
                        Err(why) => bail!("{}: {why} `{s}`", at()),
                    },
                };
                if let Some(n) = declared_nodes {
                    let worst = u.max(v);
                    if worst as usize >= n {
                        bail!("{}: vertex id {worst} out of range ({n} nodes declared)", at());
                    }
                }
                max_id = max_id.max(u).max(v);
                edges.push((u, v, w));
            }
            _ => bail!("{}: expected 'u v [w]', got {} fields", at(), parts.len()),
        }
    }
    let n = declared_nodes.unwrap_or(max_id as usize + 1);
    if !edges.is_empty() && (max_id as usize) >= n {
        bail!("{}: vertex id {max_id} out of range ({n} nodes declared)", path.display());
    }
    let mut b = GraphBuilder::new(n)
        .named(path.file_stem().and_then(|s| s.to_str()).unwrap_or("graph"));
    b.edges = edges;
    Ok(b.build())
}

/// Parse a weight column entry. NaN, negative, and non-integer weights are
/// rejected explicitly — SSSP's relaxations assume non-negative integer
/// weights, and a silently-accepted bad weight corrupts every result
/// computed on the graph.
fn parse_weight(s: &str) -> Result<Weight, &'static str> {
    if let Ok(w) = s.parse::<Weight>() {
        return if w < 0 { Err("negative weight") } else { Ok(w) };
    }
    match s.parse::<f64>() {
        Ok(x) if x.is_nan() => Err("NaN weight"),
        Ok(x) if x < 0.0 => Err("negative weight"),
        Ok(_) => Err("non-integer weight"),
        Err(_) => Err("bad weight"),
    }
}

pub fn save_edge_list(g: &Graph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# {} |V|={} |E|={}", g.name, g.num_nodes(), g.num_edges())?;
    writeln!(w, "{}", g.num_nodes())?;
    for u in 0..g.num_nodes() as Node {
        for e in g.edge_range(u) {
            writeln!(w, "{} {} {}", u, g.adj[e], g.weights[e])?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::rmat;

    #[test]
    fn roundtrip() {
        let g = rmat("rt", 64, 256, 4);
        let dir = std::env::temp_dir();
        let path = dir.join("starplat_io_test.el");
        save_edge_list(&g, &path).unwrap();
        let g2 = load_edge_list(&path).unwrap();
        assert_eq!(g.num_nodes(), g2.num_nodes());
        assert_eq!(g.adj, g2.adj);
        assert_eq!(g.weights, g2.weights);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn parses_comments_and_unweighted() {
        let dir = std::env::temp_dir();
        let path = dir.join("starplat_io_test2.el");
        std::fs::write(&path, "# hello\n% pct\n0 1\n1 2 9\n").unwrap();
        let g = load_edge_list(&path).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.weight(0), 1);
        assert_eq!(g.weight(1), 9);
        std::fs::remove_file(path).ok();
    }

    /// Write `content`, load it, and return the rendered error chain.
    fn load_err(name: &str, content: &str) -> String {
        let path = std::env::temp_dir().join(name);
        std::fs::write(&path, content).unwrap();
        let err = load_edge_list(&path).expect_err("must be rejected");
        std::fs::remove_file(&path).ok();
        format!("{err:#}")
    }

    #[test]
    fn rejects_bad_lines() {
        // every case reports the offending file and 1-based line number
        let cases = [
            ("starplat_io_arity.el", "0 1\n0 1 2 3 4\n", 2, "expected 'u v [w]'"),
            ("starplat_io_src.el", "x 1\n", 1, "bad src"),
            ("starplat_io_negsrc.el", "0 1\n-2 1\n", 2, "bad src"),
            ("starplat_io_dst.el", "0 zzz 4\n", 1, "bad dst"),
            ("starplat_io_nanw.el", "0 1 NaN\n", 1, "NaN weight"),
            ("starplat_io_negw.el", "0 1 5\n1 2 -3\n", 2, "negative weight"),
            ("starplat_io_negfw.el", "0 1 -0.5\n", 1, "negative weight"),
            ("starplat_io_fracw.el", "0 1 1.5\n", 1, "non-integer weight"),
            ("starplat_io_badw.el", "0 1 heavy\n", 1, "bad weight"),
            ("starplat_io_range.el", "3\n0 1\n1 7\n", 3, "out of range"),
        ];
        for (name, content, line, why) in cases {
            let msg = load_err(name, content);
            assert!(msg.contains(why), "`{msg}` missing `{why}`");
            assert!(msg.contains(name), "`{msg}` missing file name");
            assert!(msg.contains(&format!(":{line}:")), "`{msg}` missing line {line}");
        }
    }

    #[test]
    fn header_bounds_are_enforced_per_line() {
        // in-range ids under a header still load
        let path = std::env::temp_dir().join("starplat_io_hdr_ok.el");
        std::fs::write(&path, "3\n0 1\n1 2\n").unwrap();
        let g = load_edge_list(&path).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 2);
        std::fs::remove_file(path).ok();
    }
}
