//! Plain-text edge-list I/O.
//!
//! Format (compatible with SNAP-style lists plus an optional weight column):
//!
//! ```text
//! # comment lines start with '#' or '%'
//! <num_nodes>            (optional header; inferred from max id otherwise)
//! u v [w]
//! ```

use super::csr::{Graph, GraphBuilder, Node, Weight};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

pub fn load_edge_list(path: &Path) -> Result<Graph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    let mut edges: Vec<(Node, Node, Weight)> = Vec::new();
    let mut declared_nodes: Option<usize> = None;
    let mut max_id: Node = 0;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let parts: Vec<&str> = t.split_whitespace().collect();
        match parts.len() {
            1 if declared_nodes.is_none() && edges.is_empty() => {
                declared_nodes = Some(parts[0].parse().with_context(|| {
                    format!("{}:{}: bad node count", path.display(), lineno + 1)
                })?);
            }
            2 | 3 => {
                let u: Node = parts[0]
                    .parse()
                    .with_context(|| format!("{}:{}: bad src", path.display(), lineno + 1))?;
                let v: Node = parts[1]
                    .parse()
                    .with_context(|| format!("{}:{}: bad dst", path.display(), lineno + 1))?;
                let w: Weight = if parts.len() == 3 { parts[2].parse()? } else { 1 };
                max_id = max_id.max(u).max(v);
                edges.push((u, v, w));
            }
            _ => bail!("{}:{}: expected 'u v [w]'", path.display(), lineno + 1),
        }
    }
    let n = declared_nodes.unwrap_or(max_id as usize + 1);
    if (max_id as usize) >= n {
        bail!("edge endpoint {} out of range for {} nodes", max_id, n);
    }
    let mut b = GraphBuilder::new(n)
        .named(path.file_stem().and_then(|s| s.to_str()).unwrap_or("graph"));
    b.edges = edges;
    Ok(b.build())
}

pub fn save_edge_list(g: &Graph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# {} |V|={} |E|={}", g.name, g.num_nodes(), g.num_edges())?;
    writeln!(w, "{}", g.num_nodes())?;
    for u in 0..g.num_nodes() as Node {
        for e in g.edge_range(u) {
            writeln!(w, "{} {} {}", u, g.adj[e], g.weights[e])?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::rmat;

    #[test]
    fn roundtrip() {
        let g = rmat("rt", 64, 256, 4);
        let dir = std::env::temp_dir();
        let path = dir.join("starplat_io_test.el");
        save_edge_list(&g, &path).unwrap();
        let g2 = load_edge_list(&path).unwrap();
        assert_eq!(g.num_nodes(), g2.num_nodes());
        assert_eq!(g.adj, g2.adj);
        assert_eq!(g.weights, g2.weights);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn parses_comments_and_unweighted() {
        let dir = std::env::temp_dir();
        let path = dir.join("starplat_io_test2.el");
        std::fs::write(&path, "# hello\n% pct\n0 1\n1 2 9\n").unwrap();
        let g = load_edge_list(&path).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.weight(0), 1);
        assert_eq!(g.weight(1), 9);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_lines() {
        let dir = std::env::temp_dir();
        let path = dir.join("starplat_io_test3.el");
        std::fs::write(&path, "0 1 2 3 4\n").unwrap();
        assert!(load_edge_list(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
