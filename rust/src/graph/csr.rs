//! Compressed Sparse Row graph storage.
//!
//! The paper (§3.1) settles on CSR because the same offset-based arrays work
//! unchanged across every accelerator and the CPU. We keep exactly its
//! layout: `index_of_nodes` (offsets, |V|+1), `edge_list` (destinations, |E|),
//! `weight` (|E|), plus the reverse-CSR arrays (`rev_index_of_nodes`,
//! `src_list`) that the generated PageRank / BC-backward code pulls from.

pub type Node = u32;
pub type Weight = i32;

/// A violated CSR structural invariant, found by [`Graph::validate`].
#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
#[error("{0}")]
pub struct CsrViolation(pub String);

/// Immutable CSR graph with optional reverse adjacency and edge weights.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Forward offsets (`g.indexofNodes` in the paper's generated code).
    pub offsets: Vec<u32>,
    /// Forward destinations (`g.edgeList`).
    pub adj: Vec<Node>,
    /// Edge weights, parallel to `adj`.
    pub weights: Vec<Weight>,
    /// Reverse offsets (`g.rev_indexofNodes`).
    pub rev_offsets: Vec<u32>,
    /// Reverse sources (`g.srcList`).
    pub rev_adj: Vec<Node>,
    /// For reverse edge i, the index of the corresponding forward edge.
    pub rev_edge_id: Vec<u32>,
    /// Short display name (e.g. "RM", "US" in Table 2).
    pub name: String,
}

impl Graph {
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn num_edges(&self) -> usize {
        self.adj.len()
    }

    /// Out-neighbors of `v` (`g.neighbors(v)`).
    #[inline]
    pub fn neighbors(&self, v: Node) -> &[Node] {
        &self.adj[self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize]
    }

    /// Edge ids of `v`'s out-edges.
    #[inline]
    pub fn edge_range(&self, v: Node) -> std::ops::Range<usize> {
        self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize
    }

    /// In-neighbors of `v` (`g.nodes_to(v)` in StarPlat).
    #[inline]
    pub fn in_neighbors(&self, v: Node) -> &[Node] {
        &self.rev_adj
            [self.rev_offsets[v as usize] as usize..self.rev_offsets[v as usize + 1] as usize]
    }

    #[inline]
    pub fn out_degree(&self, v: Node) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    #[inline]
    pub fn in_degree(&self, v: Node) -> usize {
        (self.rev_offsets[v as usize + 1] - self.rev_offsets[v as usize]) as usize
    }

    /// `g.is_an_edge(u, w)` — binary search; the builder sorts adjacency.
    pub fn is_an_edge(&self, u: Node, w: Node) -> bool {
        self.neighbors(u).binary_search(&w).is_ok()
    }

    /// Weight of forward edge id `e`.
    #[inline]
    pub fn weight(&self, e: usize) -> Weight {
        self.weights[e]
    }

    /// Total weight bounds, for the DSL's `minWt`/`maxWt` aggregates.
    pub fn min_weight(&self) -> Weight {
        self.weights.iter().copied().min().unwrap_or(0)
    }
    pub fn max_weight(&self) -> Weight {
        self.weights.iter().copied().max().unwrap_or(0)
    }

    /// Undirected view check helper (used by TC tests): every edge has its
    /// reverse present.
    pub fn is_symmetric(&self) -> bool {
        (0..self.num_nodes() as Node)
            .all(|u| self.neighbors(u).iter().all(|&w| self.is_an_edge(w, u)))
    }

    /// Integrity check over both CSR halves: offsets are monotone and span
    /// the edge arrays, every adjacency entry is in range, and the reverse
    /// CSR agrees with the forward one (each reverse entry names a real
    /// forward edge with matching endpoints, and each forward edge is named
    /// exactly once).
    ///
    /// Every interpreter sweep indexes these arrays unchecked-by-design (the
    /// accelerator backends do the same on device), so the execution service
    /// runs this once at graph registration and refuses graphs that fail —
    /// a corrupt CSR must be an upfront typed error, not a mid-kernel panic.
    pub fn validate(&self) -> Result<(), CsrViolation> {
        let n = self.num_nodes();
        let m = self.adj.len();
        let fail = |msg: String| Err(CsrViolation(msg));
        if self.offsets.is_empty() {
            return fail("offsets array is empty (need |V|+1 entries)".to_string());
        }
        if self.offsets[0] != 0 {
            return fail(format!("offsets[0] = {} (want 0)", self.offsets[0]));
        }
        if self.offsets[n] as usize != m {
            return fail(format!("offsets[|V|] = {} but |E| = {m}", self.offsets[n]));
        }
        for (v, w) in self.offsets.windows(2).enumerate() {
            if w[0] > w[1] {
                return fail(format!("offsets not monotone at vertex {v}: {} > {}", w[0], w[1]));
            }
        }
        if self.weights.len() != m {
            return fail(format!("weights has {} entries but |E| = {m}", self.weights.len()));
        }
        for (e, &w) in self.adj.iter().enumerate() {
            if w as usize >= n {
                return fail(format!("adjacency entry {e} points at vertex {w} (|V| = {n})"));
            }
        }
        // reverse half: same shape rules…
        if self.rev_offsets.len() != self.offsets.len() {
            return fail(format!(
                "rev_offsets has {} entries (want {})",
                self.rev_offsets.len(),
                self.offsets.len()
            ));
        }
        if self.rev_offsets[0] != 0 || self.rev_offsets[n] as usize != m {
            return fail(format!(
                "rev_offsets spans [{}, {}] but |E| = {m}",
                self.rev_offsets[0], self.rev_offsets[n]
            ));
        }
        for (v, w) in self.rev_offsets.windows(2).enumerate() {
            if w[0] > w[1] {
                return fail(format!("rev_offsets not monotone at vertex {v}"));
            }
        }
        if self.rev_adj.len() != m || self.rev_edge_id.len() != m {
            return fail(format!(
                "reverse arrays have {}/{} entries but |E| = {m}",
                self.rev_adj.len(),
                self.rev_edge_id.len()
            ));
        }
        // …and agreement: reverse entry i under vertex v must name a forward
        // edge src→v owned by rev_adj[i]'s row, each forward edge exactly once
        let mut seen = vec![false; m];
        for v in 0..n {
            let lo = self.rev_offsets[v] as usize;
            let hi = self.rev_offsets[v + 1] as usize;
            for i in lo..hi {
                let e = self.rev_edge_id[i] as usize;
                if e >= m {
                    return fail(format!("rev_edge_id[{i}] = {e} out of range (|E| = {m})"));
                }
                if std::mem::replace(&mut seen[e], true) {
                    return fail(format!("forward edge {e} named twice by the reverse CSR"));
                }
                if self.adj[e] as usize != v {
                    return fail(format!(
                        "reverse entry {i} under vertex {v} names forward edge {e} with dst {}",
                        self.adj[e]
                    ));
                }
                let src = self.rev_adj[i] as usize;
                if src >= n {
                    return fail(format!("rev_adj[{i}] = {src} out of range (|V| = {n})"));
                }
                let owns = self.offsets[src] as usize <= e && e < self.offsets[src + 1] as usize;
                if !owns {
                    return fail(format!(
                        "reverse entry {i} claims src {src} for forward edge {e}, \
                         which is outside src's edge range"
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Mutable edge-list builder that produces a [`Graph`].
#[derive(Default, Debug)]
pub struct GraphBuilder {
    pub num_nodes: usize,
    pub edges: Vec<(Node, Node, Weight)>,
    pub name: String,
}

impl GraphBuilder {
    pub fn new(num_nodes: usize) -> Self {
        GraphBuilder { num_nodes, edges: Vec::new(), name: String::new() }
    }

    pub fn named(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    pub fn add_edge(&mut self, u: Node, v: Node, w: Weight) {
        debug_assert!((u as usize) < self.num_nodes && (v as usize) < self.num_nodes);
        self.edges.push((u, v, w));
    }

    /// Add both (u,v) and (v,u).
    pub fn add_undirected(&mut self, u: Node, v: Node, w: Weight) {
        self.add_edge(u, v, w);
        self.add_edge(v, u, w);
    }

    /// Deduplicate parallel edges (keeping the minimum weight) and drop
    /// self-loops. The paper's inputs are simple graphs.
    pub fn simplify(&mut self) {
        self.edges.retain(|&(u, v, _)| u != v);
        self.edges.sort_unstable();
        self.edges.dedup_by(|a, b| {
            if a.0 == b.0 && a.1 == b.1 {
                b.2 = b.2.min(a.2);
                true
            } else {
                false
            }
        });
    }

    /// Build CSR + reverse CSR. Adjacency is sorted per-vertex (required by
    /// `is_an_edge` binary search and the sorted-CSR TC variants).
    pub fn build(mut self) -> Graph {
        let n = self.num_nodes;
        self.edges.sort_unstable();
        let m = self.edges.len();

        let mut offsets = vec![0u32; n + 1];
        for &(u, _, _) in &self.edges {
            offsets[u as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let mut adj = Vec::with_capacity(m);
        let mut weights = Vec::with_capacity(m);
        for &(_, v, w) in &self.edges {
            adj.push(v);
            weights.push(w);
        }

        // Reverse CSR via counting sort on destination.
        let mut rev_offsets = vec![0u32; n + 1];
        for &(_, v, _) in &self.edges {
            rev_offsets[v as usize + 1] += 1;
        }
        for i in 0..n {
            rev_offsets[i + 1] += rev_offsets[i];
        }
        let mut cursor: Vec<u32> = rev_offsets[..n].to_vec();
        let mut rev_adj = vec![0 as Node; m];
        let mut rev_edge_id = vec![0u32; m];
        for (e, &(u, v, _)) in self.edges.iter().enumerate() {
            let slot = cursor[v as usize] as usize;
            rev_adj[slot] = u;
            rev_edge_id[slot] = e as u32;
            cursor[v as usize] += 1;
        }

        Graph { offsets, adj, weights, rev_offsets, rev_adj, rev_edge_id, name: self.name }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // 0 -> 1 -> 3, 0 -> 2 -> 3
        let mut b = GraphBuilder::new(4).named("diamond");
        b.add_edge(0, 1, 5);
        b.add_edge(0, 2, 2);
        b.add_edge(1, 3, 1);
        b.add_edge(2, 3, 7);
        b.build()
    }

    #[test]
    fn csr_shape() {
        let g = diamond();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[] as &[Node]);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.in_degree(3), 2);
    }

    #[test]
    fn reverse_csr_matches_forward() {
        let g = diamond();
        assert_eq!(g.in_neighbors(3), &[1, 2]);
        assert_eq!(g.in_neighbors(0), &[] as &[Node]);
        // rev_edge_id points at the right forward edge (weights agree)
        for v in 0..4u32 {
            let lo = g.rev_offsets[v as usize] as usize;
            let hi = g.rev_offsets[v as usize + 1] as usize;
            for i in lo..hi {
                let e = g.rev_edge_id[i] as usize;
                assert_eq!(g.adj[e], v);
            }
        }
    }

    #[test]
    fn is_an_edge_binary_search() {
        let g = diamond();
        assert!(g.is_an_edge(0, 2));
        assert!(!g.is_an_edge(2, 0));
        assert!(!g.is_an_edge(3, 3));
    }

    #[test]
    fn simplify_dedups_and_drops_loops() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 9);
        b.add_edge(0, 1, 4);
        b.add_edge(1, 1, 1);
        b.add_edge(2, 0, 3);
        b.simplify();
        let g = b.build();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.weight(0), 4); // min kept
    }

    #[test]
    fn undirected_symmetry() {
        let mut b = GraphBuilder::new(3);
        b.add_undirected(0, 1, 1);
        b.add_undirected(1, 2, 1);
        let g = b.build();
        assert!(g.is_symmetric());
        assert_eq!(g.num_edges(), 4);
    }

    #[test]
    fn weight_aggregates() {
        let g = diamond();
        assert_eq!(g.min_weight(), 1);
        assert_eq!(g.max_weight(), 7);
    }

    #[test]
    fn validate_accepts_built_graphs() {
        assert_eq!(diamond().validate(), Ok(()));
        let mut b = GraphBuilder::new(3);
        b.add_undirected(0, 1, 1);
        b.add_undirected(1, 2, 1);
        assert_eq!(b.build().validate(), Ok(()));
        // empty graph is structurally fine too
        assert_eq!(GraphBuilder::new(0).build().validate(), Ok(()));
    }

    #[test]
    fn validate_rejects_non_monotone_offsets() {
        let mut g = diamond();
        g.offsets.swap(1, 2);
        let err = g.validate().unwrap_err();
        assert!(err.0.contains("monotone"), "{err}");
    }

    #[test]
    fn validate_rejects_out_of_range_adjacency() {
        let mut g = diamond();
        g.adj[1] = 99;
        let err = g.validate().unwrap_err();
        assert!(err.0.contains("vertex 99"), "{err}");
    }

    #[test]
    fn validate_rejects_truncated_weights() {
        let mut g = diamond();
        g.weights.pop();
        let err = g.validate().unwrap_err();
        assert!(err.0.contains("weights"), "{err}");
    }

    #[test]
    fn validate_rejects_mismatched_offset_span() {
        let mut g = diamond();
        let last = g.offsets.len() - 1;
        g.offsets[last] -= 1;
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_reverse_disagreement() {
        // rev_edge_id pointing at a forward edge with the wrong destination
        let mut g = diamond();
        g.rev_edge_id.swap(0, 2);
        assert!(g.validate().is_err());
        // duplicate claim of one forward edge
        let mut g = diamond();
        let e = g.rev_edge_id[0];
        g.rev_edge_id[1] = e;
        assert!(g.validate().is_err());
        // rev_adj naming a vertex that does not own the forward edge
        let mut g = diamond();
        g.rev_adj[0] = 3;
        assert!(g.validate().is_err());
    }
}
