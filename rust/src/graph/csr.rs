//! Compressed Sparse Row graph storage.
//!
//! The paper (§3.1) settles on CSR because the same offset-based arrays work
//! unchanged across every accelerator and the CPU. We keep exactly its
//! layout: `index_of_nodes` (offsets, |V|+1), `edge_list` (destinations, |E|),
//! `weight` (|E|), plus the reverse-CSR arrays (`rev_index_of_nodes`,
//! `src_list`) that the generated PageRank / BC-backward code pulls from.

pub type Node = u32;
pub type Weight = i32;

/// Immutable CSR graph with optional reverse adjacency and edge weights.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Forward offsets (`g.indexofNodes` in the paper's generated code).
    pub offsets: Vec<u32>,
    /// Forward destinations (`g.edgeList`).
    pub adj: Vec<Node>,
    /// Edge weights, parallel to `adj`.
    pub weights: Vec<Weight>,
    /// Reverse offsets (`g.rev_indexofNodes`).
    pub rev_offsets: Vec<u32>,
    /// Reverse sources (`g.srcList`).
    pub rev_adj: Vec<Node>,
    /// For reverse edge i, the index of the corresponding forward edge.
    pub rev_edge_id: Vec<u32>,
    /// Short display name (e.g. "RM", "US" in Table 2).
    pub name: String,
}

impl Graph {
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn num_edges(&self) -> usize {
        self.adj.len()
    }

    /// Out-neighbors of `v` (`g.neighbors(v)`).
    #[inline]
    pub fn neighbors(&self, v: Node) -> &[Node] {
        &self.adj[self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize]
    }

    /// Edge ids of `v`'s out-edges.
    #[inline]
    pub fn edge_range(&self, v: Node) -> std::ops::Range<usize> {
        self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize
    }

    /// In-neighbors of `v` (`g.nodes_to(v)` in StarPlat).
    #[inline]
    pub fn in_neighbors(&self, v: Node) -> &[Node] {
        &self.rev_adj
            [self.rev_offsets[v as usize] as usize..self.rev_offsets[v as usize + 1] as usize]
    }

    #[inline]
    pub fn out_degree(&self, v: Node) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    #[inline]
    pub fn in_degree(&self, v: Node) -> usize {
        (self.rev_offsets[v as usize + 1] - self.rev_offsets[v as usize]) as usize
    }

    /// `g.is_an_edge(u, w)` — binary search; the builder sorts adjacency.
    pub fn is_an_edge(&self, u: Node, w: Node) -> bool {
        self.neighbors(u).binary_search(&w).is_ok()
    }

    /// Weight of forward edge id `e`.
    #[inline]
    pub fn weight(&self, e: usize) -> Weight {
        self.weights[e]
    }

    /// Total weight bounds, for the DSL's `minWt`/`maxWt` aggregates.
    pub fn min_weight(&self) -> Weight {
        self.weights.iter().copied().min().unwrap_or(0)
    }
    pub fn max_weight(&self) -> Weight {
        self.weights.iter().copied().max().unwrap_or(0)
    }

    /// Undirected view check helper (used by TC tests): every edge has its
    /// reverse present.
    pub fn is_symmetric(&self) -> bool {
        (0..self.num_nodes() as Node)
            .all(|u| self.neighbors(u).iter().all(|&w| self.is_an_edge(w, u)))
    }
}

/// Mutable edge-list builder that produces a [`Graph`].
#[derive(Default, Debug)]
pub struct GraphBuilder {
    pub num_nodes: usize,
    pub edges: Vec<(Node, Node, Weight)>,
    pub name: String,
}

impl GraphBuilder {
    pub fn new(num_nodes: usize) -> Self {
        GraphBuilder { num_nodes, edges: Vec::new(), name: String::new() }
    }

    pub fn named(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    pub fn add_edge(&mut self, u: Node, v: Node, w: Weight) {
        debug_assert!((u as usize) < self.num_nodes && (v as usize) < self.num_nodes);
        self.edges.push((u, v, w));
    }

    /// Add both (u,v) and (v,u).
    pub fn add_undirected(&mut self, u: Node, v: Node, w: Weight) {
        self.add_edge(u, v, w);
        self.add_edge(v, u, w);
    }

    /// Deduplicate parallel edges (keeping the minimum weight) and drop
    /// self-loops. The paper's inputs are simple graphs.
    pub fn simplify(&mut self) {
        self.edges.retain(|&(u, v, _)| u != v);
        self.edges.sort_unstable();
        self.edges.dedup_by(|a, b| {
            if a.0 == b.0 && a.1 == b.1 {
                b.2 = b.2.min(a.2);
                true
            } else {
                false
            }
        });
    }

    /// Build CSR + reverse CSR. Adjacency is sorted per-vertex (required by
    /// `is_an_edge` binary search and the sorted-CSR TC variants).
    pub fn build(mut self) -> Graph {
        let n = self.num_nodes;
        self.edges.sort_unstable();
        let m = self.edges.len();

        let mut offsets = vec![0u32; n + 1];
        for &(u, _, _) in &self.edges {
            offsets[u as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let mut adj = Vec::with_capacity(m);
        let mut weights = Vec::with_capacity(m);
        for &(_, v, w) in &self.edges {
            adj.push(v);
            weights.push(w);
        }

        // Reverse CSR via counting sort on destination.
        let mut rev_offsets = vec![0u32; n + 1];
        for &(_, v, _) in &self.edges {
            rev_offsets[v as usize + 1] += 1;
        }
        for i in 0..n {
            rev_offsets[i + 1] += rev_offsets[i];
        }
        let mut cursor: Vec<u32> = rev_offsets[..n].to_vec();
        let mut rev_adj = vec![0 as Node; m];
        let mut rev_edge_id = vec![0u32; m];
        for (e, &(u, v, _)) in self.edges.iter().enumerate() {
            let slot = cursor[v as usize] as usize;
            rev_adj[slot] = u;
            rev_edge_id[slot] = e as u32;
            cursor[v as usize] += 1;
        }

        Graph { offsets, adj, weights, rev_offsets, rev_adj, rev_edge_id, name: self.name }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // 0 -> 1 -> 3, 0 -> 2 -> 3
        let mut b = GraphBuilder::new(4).named("diamond");
        b.add_edge(0, 1, 5);
        b.add_edge(0, 2, 2);
        b.add_edge(1, 3, 1);
        b.add_edge(2, 3, 7);
        b.build()
    }

    #[test]
    fn csr_shape() {
        let g = diamond();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[] as &[Node]);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.in_degree(3), 2);
    }

    #[test]
    fn reverse_csr_matches_forward() {
        let g = diamond();
        assert_eq!(g.in_neighbors(3), &[1, 2]);
        assert_eq!(g.in_neighbors(0), &[] as &[Node]);
        // rev_edge_id points at the right forward edge (weights agree)
        for v in 0..4u32 {
            let lo = g.rev_offsets[v as usize] as usize;
            let hi = g.rev_offsets[v as usize + 1] as usize;
            for i in lo..hi {
                let e = g.rev_edge_id[i] as usize;
                assert_eq!(g.adj[e], v);
            }
        }
    }

    #[test]
    fn is_an_edge_binary_search() {
        let g = diamond();
        assert!(g.is_an_edge(0, 2));
        assert!(!g.is_an_edge(2, 0));
        assert!(!g.is_an_edge(3, 3));
    }

    #[test]
    fn simplify_dedups_and_drops_loops() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 9);
        b.add_edge(0, 1, 4);
        b.add_edge(1, 1, 1);
        b.add_edge(2, 0, 3);
        b.simplify();
        let g = b.build();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.weight(0), 4); // min kept
    }

    #[test]
    fn undirected_symmetry() {
        let mut b = GraphBuilder::new(3);
        b.add_undirected(0, 1, 1);
        b.add_undirected(1, 2, 1);
        let g = b.build();
        assert!(g.is_symmetric());
        assert_eq!(g.num_edges(), 4);
    }

    #[test]
    fn weight_aggregates() {
        let g = diamond();
        assert_eq!(g.min_weight(), 1);
        assert_eq!(g.max_weight(), 7);
    }
}
