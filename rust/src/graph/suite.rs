//! The ten-graph benchmark suite (Table 2 stand-ins).
//!
//! Same mix as the paper: six social networks (small-world / power-law), two
//! road networks (bounded degree, large diameter), one RMAT and one
//! uniform-random synthetic — scaled so the full (algorithm × graph ×
//! backend) matrix completes on this single-CPU testbed. Scale factors are
//! uniform within a category so the paper's intra-category ordering by |E|
//! is preserved.

use super::csr::Graph;
use super::generators::{preferential_attachment, rmat, road_grid, uniform_random};

/// Suite scale: number of vertices for the largest social graph. The default
/// keeps the whole evaluation matrix under a few minutes; STARPLAT_SCALE can
/// raise it.
pub fn default_scale() -> usize {
    std::env::var("STARPLAT_SCALE").ok().and_then(|v| v.parse().ok()).unwrap_or(4000)
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Category {
    Social,
    Road,
    Synthetic,
}

pub struct SuiteEntry {
    pub short: &'static str,
    pub paper_name: &'static str,
    pub category: Category,
    pub graph: Graph,
}

/// Build the ten graphs. Deterministic for a given `scale`.
pub fn build_suite(scale: usize) -> Vec<SuiteEntry> {
    let s = scale.max(200);
    let e = |short, paper_name, category, graph| SuiteEntry { short, paper_name, category, graph };
    // Per-graph (nodes, attach-degree) tuned to echo Table 2's avg-degree
    // ordering: TW δ̄=12, SW δ̄=4, OK δ̄=76 (densest), WK δ̄=55, LJ δ̄=28,
    // PK δ̄=37 — and the road/synthetic rows.
    vec![
        e(
            "TW",
            "twitter-2010",
            Category::Social,
            preferential_attachment("twitter-2010", s, 6, 0x7b17),
        ),
        e(
            "SW",
            "soc-sinaweibo",
            Category::Social,
            preferential_attachment("soc-sinaweibo", s * 2, 2, 0x5757),
        ),
        e("OK", "orkut", Category::Social, preferential_attachment("orkut", s / 2, 19, 0x0b0b)),
        e(
            "WK",
            "wikipedia-ru",
            Category::Social,
            preferential_attachment("wikipedia-ru", s / 2, 14, 0x3c3c),
        ),
        e(
            "LJ",
            "livejournal",
            Category::Social,
            preferential_attachment("livejournal", (s * 3) / 4, 7, 0x1111),
        ),
        e(
            "PK",
            "soc-pokec",
            Category::Social,
            preferential_attachment("soc-pokec", s / 3, 9, 0x2222),
        ),
        e("US", "usaroad", Category::Road, road_grid("usaroad", side(s * 2), side(s * 2), 0x4444)),
        e("GR", "germany-osm", Category::Road, road_grid("germany-osm", side(s), side(s), 0x5555)),
        e("RM", "rmat876", Category::Synthetic, rmat("rmat876", s, s * 5, 0x6666)),
        e(
            "UR",
            "uniform-random",
            Category::Synthetic,
            uniform_random("uniform-random", s, s * 4, 0x7777),
        ),
    ]
}

fn side(n: usize) -> usize {
    (n as f64).sqrt().ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::stats;

    #[test]
    fn suite_has_ten_graphs_with_right_shapes() {
        let suite = build_suite(600);
        assert_eq!(suite.len(), 10);
        for s in &suite {
            assert!(s.graph.num_nodes() > 0);
            assert!(s.graph.num_edges() > 0, "{} empty", s.short);
        }
        // road networks: small max degree; social: hubs
        let us = stats(&suite[6].graph, "US");
        let tw = stats(&suite[0].graph, "TW");
        assert!(us.max_degree <= 10);
        assert!(tw.max_degree as f64 > 4.0 * tw.avg_degree);
        // roads have much larger diameter proxy than socials
        assert!(us.ecc_from_0 > 4 * tw.ecc_from_0);
    }

    #[test]
    fn suite_deterministic() {
        let a = build_suite(300);
        let b = build_suite(300);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.graph.adj, y.graph.adj);
        }
    }
}
