//! Graph statistics — the columns of the paper's Table 2.

use super::csr::{Graph, Node};

#[derive(Clone, Debug)]
pub struct GraphStats {
    pub name: String,
    pub short: String,
    pub num_nodes: usize,
    pub num_edges: usize,
    pub avg_degree: f64,
    pub max_degree: usize,
    /// BFS eccentricity from vertex 0 — a cheap diameter proxy separating
    /// road-like (large) from social (small) inputs.
    pub ecc_from_0: usize,
}

pub fn stats(g: &Graph, short: &str) -> GraphStats {
    let n = g.num_nodes();
    let degs: Vec<usize> = (0..n as Node).map(|v| g.out_degree(v)).collect();
    let max_degree = degs.iter().copied().max().unwrap_or(0);
    let avg_degree = if n > 0 { g.num_edges() as f64 / n as f64 } else { 0.0 };

    // BFS from 0 for an eccentricity proxy.
    let mut level = vec![u32::MAX; n];
    let mut frontier = vec![0 as Node];
    if n > 0 {
        level[0] = 0;
    }
    let mut depth = 0u32;
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for &u in &frontier {
            for &w in g.neighbors(u) {
                if level[w as usize] == u32::MAX {
                    level[w as usize] = depth + 1;
                    next.push(w);
                }
            }
        }
        depth += 1;
        frontier = next;
    }
    let ecc_from_0 =
        level.iter().filter(|&&l| l != u32::MAX).map(|&l| l as usize).max().unwrap_or(0);

    GraphStats {
        name: g.name.clone(),
        short: short.to_string(),
        num_nodes: n,
        num_edges: g.num_edges(),
        avg_degree,
        max_degree,
        ecc_from_0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::GraphBuilder;

    #[test]
    fn stats_of_star() {
        let mut b = GraphBuilder::new(5).named("star");
        for v in 1..5 {
            b.add_undirected(0, v, 1);
        }
        let g = b.build();
        let s = stats(&g, "ST");
        assert_eq!(s.num_nodes, 5);
        assert_eq!(s.num_edges, 8);
        assert_eq!(s.max_degree, 4);
        assert!((s.avg_degree - 1.6).abs() < 1e-9);
        assert_eq!(s.ecc_from_0, 1);
    }
}
