//! Graph storage, generators, statistics and I/O (paper §3.1 substrate).

pub mod csr;
pub mod ell;
pub mod generators;
pub mod io;
pub mod stats;
pub mod suite;

pub use csr::{Graph, GraphBuilder, Node, Weight};
pub use ell::{BitmapAdjacency, EllGraph};
