//! ELL (padded, "sliced-CSR") layout for the XLA accelerator backend.
//!
//! XLA wants dense rectangular arrays; we pad every vertex's neighbor list to
//! a common width `width` with sentinel entries (self-index, masked weight).
//! This is the TPU-flavoured analogue of the paper's warp-per-vertex CSR
//! traversal: the `[N, width]` index/weight matrices tile cleanly into VMEM
//! blocks via Pallas BlockSpec (see DESIGN.md §2).
//!
//! A pull-mode (in-edge) variant is also built, because the XLA kernels use
//! pull formulations to avoid scatter atomics.

use super::csr::{Graph, Node};

#[derive(Clone, Debug)]
pub struct EllGraph {
    /// Number of real vertices.
    pub n: usize,
    /// Padded vertex count (rounded up to `row_pad` multiple for tiling).
    pub n_pad: usize,
    /// Neighbor-list width (max degree, rounded up to `width_pad` multiple).
    pub width: usize,
    /// `[n_pad * width]` row-major neighbor indices; sentinel = own row index.
    pub idx: Vec<u32>,
    /// `[n_pad * width]` weights; sentinel entries get 0.
    pub wgt: Vec<i32>,
    /// `[n_pad * width]` validity mask (1.0 real edge / 0.0 padding).
    pub mask: Vec<f32>,
    /// `[n_pad]` real out-degrees (0 for padding rows).
    pub degree: Vec<i32>,
}

fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m.max(1)) * m.max(1)
}

impl EllGraph {
    /// Pack the *out*-adjacency (push direction).
    pub fn from_csr_out(g: &Graph, row_pad: usize, width_pad: usize) -> EllGraph {
        Self::pack(g, false, row_pad, width_pad)
    }

    /// Pack the *in*-adjacency (pull direction; what the XLA kernels use).
    pub fn from_csr_in(g: &Graph, row_pad: usize, width_pad: usize) -> EllGraph {
        Self::pack(g, true, row_pad, width_pad)
    }

    fn pack(g: &Graph, pull: bool, row_pad: usize, width_pad: usize) -> EllGraph {
        let n = g.num_nodes();
        let n_pad = round_up(n.max(1), row_pad);
        let max_deg = (0..n as Node)
            .map(|v| if pull { g.in_degree(v) } else { g.out_degree(v) })
            .max()
            .unwrap_or(0);
        let width = round_up(max_deg.max(1), width_pad);

        let mut idx = vec![0u32; n_pad * width];
        let mut wgt = vec![0i32; n_pad * width];
        let mut mask = vec![0f32; n_pad * width];
        let mut degree = vec![0i32; n_pad];

        for v in 0..n {
            let row = v * width;
            // Sentinel: point at self so gathers stay in-bounds.
            for k in 0..width {
                idx[row + k] = v as u32;
            }
            if pull {
                let lo = g.rev_offsets[v] as usize;
                let hi = g.rev_offsets[v + 1] as usize;
                degree[v] = (hi - lo) as i32;
                for (k, i) in (lo..hi).enumerate() {
                    idx[row + k] = g.rev_adj[i];
                    wgt[row + k] = g.weights[g.rev_edge_id[i] as usize];
                    mask[row + k] = 1.0;
                }
            } else {
                let lo = g.offsets[v] as usize;
                let hi = g.offsets[v + 1] as usize;
                degree[v] = (hi - lo) as i32;
                for (k, i) in (lo..hi).enumerate() {
                    idx[row + k] = g.adj[i];
                    wgt[row + k] = g.weights[i];
                    mask[row + k] = 1.0;
                }
            }
        }
        // Padding rows: self-loops at index (n_pad-1 safe) — keep idx row = own
        // index so gathers read the padding row itself.
        for v in n..n_pad {
            let row = v * width;
            for k in 0..width {
                idx[row + k] = v as u32;
            }
        }

        EllGraph { n, n_pad, width, idx, wgt, mask, degree }
    }

    /// Out-degree vector for *forward* CSR regardless of pack direction —
    /// needed by PageRank's `rank/outdeg` term.
    pub fn out_degrees(g: &Graph, n_pad: usize) -> Vec<f32> {
        let mut d = vec![0f32; n_pad];
        for v in 0..g.num_nodes() {
            d[v] = g.out_degree(v as Node) as f32;
        }
        d
    }

    /// Total padded element count (VMEM-footprint estimation input).
    pub fn padded_elems(&self) -> usize {
        self.n_pad * self.width
    }

    /// Fraction of padding (1 - fill ratio); reported in DESIGN.md §Perf.
    pub fn padding_overhead(&self) -> f64 {
        let real: i64 = self.degree.iter().map(|&d| d as i64).sum();
        1.0 - real as f64 / self.padded_elems() as f64
    }
}

/// Dense adjacency bitmap for the triangle-counting kernel: row `v` packs
/// neighbor membership into `ceil(n_pad/32)` u32 words.
pub struct BitmapAdjacency {
    pub n: usize,
    pub words: usize,
    pub bits: Vec<u32>, // [n * words]
}

impl BitmapAdjacency {
    pub fn from_csr(g: &Graph, row_pad: usize) -> BitmapAdjacency {
        let n = round_up(g.num_nodes().max(1), row_pad);
        let words = round_up(n.div_ceil(32), 1);
        let mut bits = vec![0u32; n * words];
        for u in 0..g.num_nodes() as Node {
            for &w in g.neighbors(u) {
                bits[u as usize * words + (w as usize) / 32] |= 1 << (w % 32);
            }
        }
        BitmapAdjacency { n, words, bits }
    }

    pub fn has_edge(&self, u: usize, w: usize) -> bool {
        self.bits[u * self.words + w / 32] & (1 << (w % 32)) != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::GraphBuilder;

    fn path3() -> Graph {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 10);
        b.add_edge(1, 2, 20);
        b.build()
    }

    #[test]
    fn ell_out_preserves_edges() {
        let g = path3();
        let e = EllGraph::from_csr_out(&g, 4, 8);
        assert_eq!(e.n, 3);
        assert_eq!(e.n_pad, 4);
        assert_eq!(e.width, 8);
        assert_eq!(e.idx[0], 1);
        assert_eq!(e.wgt[0], 10);
        assert_eq!(e.mask[0], 1.0);
        // sentinel slots point at self with zero mask
        assert_eq!(e.idx[1], 0);
        assert_eq!(e.mask[1], 0.0);
        assert_eq!(e.degree, vec![1, 1, 0, 0]);
    }

    #[test]
    fn ell_in_is_pull_view() {
        let g = path3();
        let e = EllGraph::from_csr_in(&g, 1, 1);
        assert_eq!(e.width, 1);
        assert_eq!(e.idx[1], 0); // node 1 pulls from node 0
        assert_eq!(e.wgt[1], 10);
        assert_eq!(e.idx[2], 1);
        assert_eq!(e.wgt[2], 20);
        assert_eq!(e.mask[0], 0.0); // node 0 has no in-edges
    }

    #[test]
    fn ell_edge_conservation_random() {
        let mut rng = crate::util::rng::Rng::new(11);
        for _ in 0..10 {
            let n = rng.range(2, 40);
            let mut b = GraphBuilder::new(n);
            for _ in 0..rng.range(0, 4 * n) {
                let u = rng.range(0, n) as Node;
                let v = rng.range(0, n) as Node;
                if u != v {
                    b.add_edge(u, v, rng.range(1, 100) as i32);
                }
            }
            b.simplify();
            let g = b.build();
            let e = EllGraph::from_csr_out(&g, 8, 4);
            let packed: usize = e.mask.iter().map(|&m| m as usize).sum();
            assert_eq!(packed, g.num_edges());
            // every masked entry corresponds to a real edge
            for v in 0..e.n {
                for k in 0..e.width {
                    if e.mask[v * e.width + k] == 1.0 {
                        assert!(g.is_an_edge(v as Node, e.idx[v * e.width + k]));
                    }
                }
            }
        }
    }

    #[test]
    fn bitmap_matches_csr() {
        let g = path3();
        let bm = BitmapAdjacency::from_csr(&g, 8);
        assert!(bm.has_edge(0, 1));
        assert!(bm.has_edge(1, 2));
        assert!(!bm.has_edge(1, 0));
        assert!(!bm.has_edge(2, 2));
    }

    #[test]
    fn padding_overhead_bounds() {
        let g = path3();
        let e = EllGraph::from_csr_out(&g, 1, 1);
        let oh = e.padding_overhead();
        assert!((0.0..1.0).contains(&oh));
    }
}
