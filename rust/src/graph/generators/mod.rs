//! Synthetic graph generators standing in for the paper's Table-2 inputs.
//!
//! We cannot download twitter-2010 / orkut / usaroad here, so each generator
//! reproduces the *shape class* that drives the paper's qualitative results:
//! power-law degree + small diameter (social nets, RMAT), bounded degree +
//! large diameter (road networks), and uniform-random.

pub mod grid;
pub mod line;
pub mod rmat;
pub mod smallworld;
pub mod uniform;

pub use grid::road_grid;
pub use line::{path_graph, star_graph};
pub use rmat::rmat;
pub use smallworld::preferential_attachment;
pub use uniform::uniform_random;

use crate::graph::csr::{Graph, GraphBuilder, Node};
use crate::util::rng::Rng;

/// Assign uniform-random weights in [1, 100] — the paper's convention for
/// unweighted inputs ("we assign edge-weights selected uniformly at random
/// in the range [1,100]").
pub fn assign_uniform_weights(b: &mut GraphBuilder, seed: u64) {
    let mut rng = Rng::new(seed ^ 0x77ee77ee);
    for e in &mut b.edges {
        e.2 = rng.range(1, 101) as i32;
    }
}

/// Make the edge set symmetric (undirected view) — TC and BC expect this.
pub fn symmetrize(b: &mut GraphBuilder) {
    let mut extra = Vec::with_capacity(b.edges.len());
    for &(u, v, w) in &b.edges {
        extra.push((v, u, w));
    }
    b.edges.extend(extra);
    b.simplify();
}

/// Ensure weak connectivity by chaining components along a random spanning
/// thread; keeps diameter behaviour intact while making SSSP/BFS reach all.
pub fn connect_components(b: &mut GraphBuilder, seed: u64, undirected: bool) {
    let n = b.num_nodes;
    if n == 0 {
        return;
    }
    // Union-find over current edges.
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(p: &mut [u32], x: u32) -> u32 {
        let mut r = x;
        while p[r as usize] != r {
            p[r as usize] = p[p[r as usize] as usize];
            r = p[r as usize];
        }
        r
    }
    for &(u, v, _) in &b.edges {
        let (ru, rv) = (find(&mut parent, u), find(&mut parent, v));
        if ru != rv {
            parent[ru as usize] = rv;
        }
    }
    let mut rng = Rng::new(seed ^ 0xc0ffee);
    let mut reps: Vec<u32> = Vec::new();
    for v in 0..n as u32 {
        if find(&mut parent, v) == v {
            reps.push(v);
        }
    }
    rng.shuffle(&mut reps);
    for w in reps.windows(2) {
        let wgt = rng.range(1, 101) as i32;
        b.add_edge(w[0], w[1], wgt);
        if undirected {
            b.add_edge(w[1], w[0], wgt);
        }
        let (r0, r1) = (find(&mut parent, w[0]), find(&mut parent, w[1]));
        parent[r0 as usize] = r1;
    }
}

/// Sample `k` distinct source vertices with non-zero out-degree — the
/// `sourceSet` for BC (the paper runs 1 / 20 / 80 / 150 sources).
pub fn sample_sources(g: &Graph, k: usize, seed: u64) -> Vec<Node> {
    let mut rng = Rng::new(seed ^ 0x5eed);
    let candidates: Vec<Node> =
        (0..g.num_nodes() as Node).filter(|&v| g.out_degree(v) > 0).collect();
    if candidates.is_empty() {
        return vec![];
    }
    let k = k.min(candidates.len());
    rng.sample_distinct(candidates.len(), k).into_iter().map(|i| candidates[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connect_makes_reachable() {
        // two isolated cliques -> connected after fix-up
        let mut b = GraphBuilder::new(6);
        for &(u, v) in &[(0, 1), (1, 2), (3, 4), (4, 5)] {
            b.add_undirected(u, v, 1);
        }
        connect_components(&mut b, 1, true);
        let g = b.build();
        // BFS from 0 reaches everything
        let mut seen = vec![false; 6];
        let mut q = vec![0u32];
        seen[0] = true;
        while let Some(u) = q.pop() {
            for &w in g.neighbors(u) {
                if !seen[w as usize] {
                    seen[w as usize] = true;
                    q.push(w);
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sources_are_distinct_and_valid() {
        let g = rmat("t", 64, 256, 42);
        let s = sample_sources(&g, 10, 7);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), s.len());
        assert!(s.iter().all(|&v| g.out_degree(v) > 0));
    }
}
