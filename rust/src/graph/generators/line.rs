//! Degenerate-topology generators: path and star graphs.
//!
//! Neither shape appears in the paper's Table 2 — they exist for the
//! differential-test families (`tests/planexec_parity.rs`): a path maximizes
//! diameter (many BFS levels / fixedPoint rounds with tiny frontiers), a
//! star maximizes single-vertex degree (one dense frontier, depth 2). Both
//! are the classic boundary cases for level-synchronous skeletons and
//! direction-optimized traversal.

use crate::graph::csr::{Graph, GraphBuilder, Node};
use crate::util::rng::Rng;

/// Undirected path `0 — 1 — … — n-1` with seeded uniform weights in
/// [1, 100] (pass `unit_weights` for an unweighted view — all weights 1).
pub fn path_graph(name: &str, num_nodes: usize, seed: u64, unit_weights: bool) -> Graph {
    assert!(num_nodes >= 2);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(num_nodes).named(name);
    for v in 0..num_nodes - 1 {
        let w = if unit_weights { 1 } else { rng.range(1, 101) as i32 };
        b.add_undirected(v as Node, v as Node + 1, w);
    }
    b.build()
}

/// Undirected star: hub 0 joined to every leaf `1..n-1`, seeded uniform
/// weights in [1, 100] (`unit_weights` for the unweighted view).
pub fn star_graph(name: &str, num_nodes: usize, seed: u64, unit_weights: bool) -> Graph {
    assert!(num_nodes >= 2);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(num_nodes).named(name);
    for v in 1..num_nodes {
        let w = if unit_weights { 1 } else { rng.range(1, 101) as i32 };
        b.add_undirected(0, v as Node, w);
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_shape() {
        let g = path_graph("p", 10, 1, false);
        assert_eq!(g.num_nodes(), 10);
        assert_eq!(g.num_edges(), 18); // 9 undirected edges
        assert_eq!(g.out_degree(0), 1);
        assert_eq!(g.out_degree(5), 2);
        // deterministic under the same seed
        assert_eq!(g.weights, path_graph("p", 10, 1, false).weights);
        assert!(path_graph("p", 10, 1, true).weights.iter().all(|&w| w == 1));
    }

    #[test]
    fn star_shape() {
        let g = star_graph("s", 8, 2, false);
        assert_eq!(g.out_degree(0), 7);
        assert!((1..8u32).all(|v| g.out_degree(v) == 1));
        assert!((1..8u32).all(|v| g.is_an_edge(0, v) && g.is_an_edge(v, 0)));
    }
}
