//! Recursive-MATrix (R-MAT) generator.
//!
//! Matches the paper's synthetic rmat876 input: "generated using SNAP's RMAT
//! generator with parameters a=0.57, b=0.19, c=0.19, d=0.05" — a skewed,
//! power-law degree distribution with small diameter.

use crate::graph::csr::{Graph, GraphBuilder, Node};
use crate::util::rng::Rng;

pub const A: f64 = 0.57;
pub const B: f64 = 0.19;
pub const C: f64 = 0.19;
// d = 0.05 (implied remainder)

/// Generate a directed R-MAT graph with ~`num_edges` edges over
/// `num_nodes` (rounded up to a power of two internally, then mapped down).
pub fn rmat(name: &str, num_nodes: usize, num_edges: usize, seed: u64) -> Graph {
    rmat_with(name, num_nodes, num_edges, seed, A, B, C)
}

pub fn rmat_with(
    name: &str,
    num_nodes: usize,
    num_edges: usize,
    seed: u64,
    a: f64,
    b: f64,
    c: f64,
) -> Graph {
    assert!(num_nodes >= 2);
    let scale = usize::BITS - (num_nodes - 1).leading_zeros();
    let side = 1usize << scale;
    let mut rng = Rng::new(seed);
    let mut builder = GraphBuilder::new(num_nodes).named(name);

    let mut placed = 0usize;
    let mut attempts = 0usize;
    while placed < num_edges && attempts < num_edges * 20 {
        attempts += 1;
        let (mut u, mut v) = (0usize, 0usize);
        let mut len = side;
        while len > 1 {
            len /= 2;
            let r = rng.f64();
            // noise keeps the distribution from being too deterministic,
            // like SNAP's smoothed R-MAT.
            let (pa, pb, pc) = (a, b, c);
            if r < pa {
                // top-left
            } else if r < pa + pb {
                v += len;
            } else if r < pa + pb + pc {
                u += len;
            } else {
                u += len;
                v += len;
            }
        }
        if u >= num_nodes || v >= num_nodes || u == v {
            continue;
        }
        builder.add_edge(u as Node, v as Node, rng.range(1, 101) as i32);
        placed += 1;
    }
    super::symmetrize(&mut builder);
    super::connect_components(&mut builder, seed, true);
    builder.simplify();
    builder.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skewed_degree_distribution() {
        let g = rmat("rm", 1024, 8192, 123);
        assert!(g.num_nodes() == 1024);
        assert!(g.num_edges() > 4096);
        let max_deg = (0..1024u32).map(|v| g.out_degree(v)).max().unwrap();
        let avg = g.num_edges() as f64 / 1024.0;
        // R-MAT with these params gives a heavy hub: max ≫ avg.
        assert!(
            (max_deg as f64) > 6.0 * avg,
            "max degree {max_deg} not skewed vs avg {avg:.1}"
        );
    }

    #[test]
    fn deterministic() {
        let a = rmat("x", 256, 1024, 5);
        let b = rmat("x", 256, 1024, 5);
        assert_eq!(a.adj, b.adj);
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn symmetric_and_simple() {
        let g = rmat("x", 128, 512, 9);
        assert!(g.is_symmetric());
        for v in 0..g.num_nodes() as Node {
            let nb = g.neighbors(v);
            assert!(nb.windows(2).all(|w| w[0] < w[1]), "sorted, no dup");
            assert!(!nb.contains(&v), "no self loop");
        }
    }
}
