//! Uniform-random (Erdős–Rényi G(n,m)-style) generator.
//!
//! Stand-in for the paper's `uniform-random` input "generated using
//! Green-Marl's graph generator": every edge endpoint uniform, giving a
//! tight binomial degree distribution (Table 2 shows avg δ=8, max δ=27).

use crate::graph::csr::{Graph, GraphBuilder, Node};
use crate::util::rng::Rng;

pub fn uniform_random(name: &str, num_nodes: usize, num_edges: usize, seed: u64) -> Graph {
    assert!(num_nodes >= 2);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(num_nodes).named(name);
    let mut placed = 0usize;
    let mut attempts = 0usize;
    while placed < num_edges && attempts < num_edges * 20 {
        attempts += 1;
        let u = rng.range(0, num_nodes) as Node;
        let v = rng.range(0, num_nodes) as Node;
        if u == v {
            continue;
        }
        b.add_edge(u, v, rng.range(1, 101) as i32);
        placed += 1;
    }
    super::symmetrize(&mut b);
    super::connect_components(&mut b, seed, true);
    b.simplify();
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tight_degree_distribution() {
        let g = uniform_random("ur", 1000, 8000, 77);
        let degs: Vec<usize> = (0..1000u32).map(|v| g.out_degree(v)).collect();
        let avg = degs.iter().sum::<usize>() as f64 / 1000.0;
        let max = *degs.iter().max().unwrap() as f64;
        // Uniform-random: max degree only a small multiple of the average
        // (paper: avg 8 vs max 27).
        assert!(max < 5.0 * avg, "max {max} vs avg {avg}");
    }

    #[test]
    fn deterministic_and_connected() {
        let a = uniform_random("u", 128, 512, 3);
        let b = uniform_random("u", 128, 512, 3);
        assert_eq!(a.adj, b.adj);
        // connected: BFS reaches all
        let mut seen = vec![false; 128];
        let mut q = vec![0u32];
        seen[0] = true;
        while let Some(u) = q.pop() {
            for &w in a.neighbors(u) {
                if !seen[w as usize] {
                    seen[w as usize] = true;
                    q.push(w);
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
