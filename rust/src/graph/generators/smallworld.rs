//! Preferential-attachment (Barabási–Albert-style) generator.
//!
//! Stand-in for the paper's six social networks (twitter-2010, soc-sinaweibo,
//! orkut, wikipedia-ru, livejournal, soc-pokec): small-world property —
//! power-law degrees with huge hubs (Table 2 max δ up to 302,779) and a tiny
//! diameter. Each new vertex attaches `m` edges to endpoints sampled
//! proportionally to degree (implemented with the repeated-endpoint trick).

use crate::graph::csr::{Graph, GraphBuilder, Node};
use crate::util::rng::Rng;

pub fn preferential_attachment(name: &str, num_nodes: usize, m: usize, seed: u64) -> Graph {
    assert!(num_nodes > m && m >= 1);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(num_nodes).named(name);
    // endpoint pool: vertex v appears deg(v) times -> degree-proportional pick
    let mut pool: Vec<Node> = Vec::with_capacity(2 * num_nodes * m);

    // seed clique over the first m+1 vertices
    for u in 0..=(m as Node) {
        for v in 0..u {
            b.add_undirected(u, v, rng.range(1, 101) as i32);
            pool.push(u);
            pool.push(v);
        }
    }
    for v in (m + 1)..num_nodes {
        let mut targets: std::collections::BTreeSet<Node> = std::collections::BTreeSet::new();
        let mut guard = 0;
        while targets.len() < m && guard < 50 * m {
            guard += 1;
            let t = pool[rng.range(0, pool.len())];
            if t as usize != v {
                targets.insert(t);
            }
        }
        for &t in &targets {
            b.add_undirected(v as Node, t, rng.range(1, 101) as i32);
            pool.push(v as Node);
            pool.push(t);
        }
    }
    b.simplify();
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_law_hubs_and_small_world() {
        let g = preferential_attachment("ok", 2000, 8, 99);
        let degs: Vec<usize> = (0..2000u32).map(|v| g.out_degree(v)).collect();
        let avg = degs.iter().sum::<usize>() as f64 / 2000.0;
        let max = *degs.iter().max().unwrap() as f64;
        assert!(max > 8.0 * avg, "expected hub: max {max} vs avg {avg}");
        assert!(g.is_symmetric());
    }

    #[test]
    fn deterministic() {
        let a = preferential_attachment("s", 300, 4, 1);
        let b = preferential_attachment("s", 300, 4, 1);
        assert_eq!(a.adj, b.adj);
    }

    #[test]
    fn all_vertices_connected() {
        let g = preferential_attachment("s", 500, 3, 2);
        assert!((0..500u32).all(|v| g.out_degree(v) >= 1));
    }
}
