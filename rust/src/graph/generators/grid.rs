//! Road-network generator: a 2-D grid with perturbations.
//!
//! Stand-in for the paper's `usaroad` / `germany-osm` inputs: average degree
//! ≈ 2–4, tiny maximum degree (9 / 13), and a very large diameter — the
//! combination that makes level-synchronous BFS/BC slow in Tables 3–4 (the
//! paper's road-network rows dominate BC totals). A grid of side s has
//! diameter Θ(s) = Θ(√V), reproducing that regime.

use crate::graph::csr::{Graph, GraphBuilder, Node};
use crate::util::rng::Rng;

/// `rows × cols` 4-connected grid; `drop_p` randomly removes street segments
/// (keeping connectivity via the component fix-up), `diag_p` adds a few
/// diagonal shortcuts so max degree varies like real road data.
pub fn road_grid(name: &str, rows: usize, cols: usize, seed: u64) -> Graph {
    let n = rows * cols;
    assert!(n >= 2);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(n).named(name);
    let id = |r: usize, c: usize| (r * cols + c) as Node;
    let drop_p = 0.08;
    let diag_p = 0.02;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols && !rng.chance(drop_p) {
                b.add_undirected(id(r, c), id(r, c + 1), rng.range(1, 101) as i32);
            }
            if r + 1 < rows && !rng.chance(drop_p) {
                b.add_undirected(id(r, c), id(r + 1, c), rng.range(1, 101) as i32);
            }
            if r + 1 < rows && c + 1 < cols && rng.chance(diag_p) {
                b.add_undirected(id(r, c), id(r + 1, c + 1), rng.range(1, 101) as i32);
            }
        }
    }
    super::connect_components(&mut b, seed, true);
    b.simplify();
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bfs_ecc(g: &Graph, src: Node) -> usize {
        let mut level = vec![usize::MAX; g.num_nodes()];
        level[src as usize] = 0;
        let mut frontier = vec![src];
        let mut depth = 0;
        while !frontier.is_empty() {
            let mut next = Vec::new();
            for &u in &frontier {
                for &w in g.neighbors(u) {
                    if level[w as usize] == usize::MAX {
                        level[w as usize] = depth + 1;
                        next.push(w);
                    }
                }
            }
            depth += 1;
            frontier = next;
        }
        level.iter().filter(|&&l| l != usize::MAX).max().copied().unwrap_or(0)
    }

    #[test]
    fn road_shape_low_degree_high_diameter() {
        let g = road_grid("us", 40, 40, 42);
        assert_eq!(g.num_nodes(), 1600);
        let max_deg = (0..1600u32).map(|v| g.out_degree(v)).max().unwrap();
        assert!(max_deg <= 10, "road max degree should be tiny, got {max_deg}");
        let avg = g.num_edges() as f64 / 1600.0;
        assert!(avg < 5.0);
        // diameter ~ Θ(side): eccentricity from a corner ≥ side
        assert!(bfs_ecc(&g, 0) >= 40, "grid should have large diameter");
    }

    #[test]
    fn deterministic() {
        let a = road_grid("g", 10, 12, 5);
        let b = road_grid("g", 10, 12, 5);
        assert_eq!(a.adj, b.adj);
    }
}
