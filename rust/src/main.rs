fn main() { starplat::cli::main(); }
